//! The scenario DSL: one line of text fully determines a simulated run.
//!
//! A scenario is whitespace-separated `key=value` tokens; every key is
//! optional and overrides a deterministic default. Example — "8 workers,
//! 2 shards, crash worker 3 at t=5 s, hybrid step-50 schedule":
//!
//! ```text
//! workers=8 shards=2 policy=hybrid:step:50 secs=10 faults=crash:3@5
//! ```
//!
//! | key          | meaning                                   | default        |
//! |--------------|-------------------------------------------|----------------|
//! | `workers`    | gradient workers                          | 8              |
//! | `shards`     | parameter-server shards                   | 1              |
//! | `policy`     | `Policy::parse` syntax                    | `hybrid:step:50` |
//! | `secs`       | virtual training budget (seconds)         | 10             |
//! | `seed`       | master seed (all streams derive from it)  | 0              |
//! | `lr`         | learning rate                             | 0.05           |
//! | `kmax`       | threshold cap (absent → worker count)     | absent         |
//! | `steps`      | per-worker submission budget (`--steps`)  | absent         |
//! | `grad-ms`    | virtual compute time per gradient (ms)    | 5              |
//! | `floor-ms`   | compute-cost floor per iteration (ms)     | 0              |
//! | `eval-ms`    | metric sampling interval (ms)             | 500            |
//! | `delay-frac` | fraction of workers subject to delays     | 0              |
//! | `delay-mean` | delay Normal mean (seconds)               | 0              |
//! | `delay-std`  | delay Normal σ (seconds)                  | 0              |
//! | `delay-dist` | delay family (`normal`, `lognormal`)      | `normal`       |
//! | `delay-regions` | WAN regional correlation groups (0 = off) | 0          |
//! | `faults`     | a [`FaultPlan`] clause list               | none           |
//! | `compress`   | gradient [`WireFormat`] (`dense`, `topk:<k|frac>`, `int8`, `topk+int8:<k|frac>`) | `dense` |
//! | `elastic`    | `on`/`off`: renormalize K and barriers to live membership | `off` |
//! | `quorum`     | barrier-denominator floor under `elastic` | 1              |
//! | `aggregate`  | server aggregation (`mean`, `clip:<c>`, `trimmed:<f>`, `median`) | `mean` |
//! | `partition`  | data partition (`iid`, `dirichlet:<alpha>`) | `iid`        |
//!
//! `Display` renders the canonical form; `parse(display(s))` is the
//! identity, so scenarios can be logged from one run and replayed in
//! another (EXPERIMENTS.md records sweeps this way).

use super::super::buffer::AggregateMode;
use super::super::compress::WireFormat;
use super::super::delay::{DelayDist, DelayModel};
use super::super::policy::Policy;
use super::super::threshold::Schedule;
use super::super::trainer::TrainConfig;
use super::fault::FaultPlan;
use std::time::Duration;

/// Everything that determines a simulated run besides the workload
/// (engines, data and init come from `RunInputs`, exactly as for the
/// threaded trainer).
#[derive(Clone, Debug)]
pub struct Scenario {
    /// The shared coordinator configuration; `duration` is *virtual* time.
    pub train: TrainConfig,
    /// Virtual compute cost per gradient (the simulator's stand-in for the
    /// paper's per-iteration ray + PyTorch cost).
    pub grad_time: Duration,
    /// Injected faults; empty = fault-free run.
    pub faults: FaultPlan,
}

impl Scenario {
    /// A scenario with the given policy/worker-count/budget and the
    /// defaults from the table above (no delays, no faults).
    pub fn base(policy: Policy, workers: usize, secs: f64) -> Scenario {
        let mut train = TrainConfig::quick(policy, workers, secs);
        train.delay = DelayModel::none();
        train.lr = 0.05;
        Scenario {
            train,
            grad_time: Duration::from_millis(5),
            faults: FaultPlan::default(),
        }
    }

    /// Parse the `key=value` DSL (see the module docs).
    pub fn parse(spec: &str) -> anyhow::Result<Scenario> {
        let mut scn = Scenario::base(
            Policy::Hybrid {
                schedule: Schedule::Step { step: 50 },
                strict: false,
            },
            8,
            10.0,
        );
        for tok in spec.split_whitespace() {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad scenario token `{tok}` (expected key=value)"))?;
            let num = |what: &str| anyhow::anyhow!("bad {what} `{v}` in `{tok}`");
            match k {
                "workers" => scn.train.workers = v.parse().map_err(|_| num("worker count"))?,
                "shards" => scn.train.shards = v.parse().map_err(|_| num("shard count"))?,
                "policy" => scn.train.policy = Policy::parse(v)?,
                "secs" => {
                    let s: f64 = v.parse().map_err(|_| num("duration"))?;
                    anyhow::ensure!(s > 0.0 && s.is_finite(), "secs must be > 0");
                    scn.train.duration = Duration::from_secs_f64(s);
                }
                "seed" => scn.train.seed = v.parse().map_err(|_| num("seed"))?,
                "lr" => scn.train.lr = v.parse().map_err(|_| num("learning rate"))?,
                "kmax" => scn.train.k_max = Some(v.parse().map_err(|_| num("kmax"))?),
                "steps" => scn.train.steps = Some(v.parse().map_err(|_| num("steps"))?),
                "grad-ms" => {
                    let ms: f64 = v.parse().map_err(|_| num("grad-ms"))?;
                    anyhow::ensure!(ms > 0.0 && ms.is_finite(), "grad-ms must be > 0");
                    scn.grad_time = Duration::from_secs_f64(ms / 1000.0);
                }
                "floor-ms" => {
                    let ms: f64 = v.parse().map_err(|_| num("floor-ms"))?;
                    anyhow::ensure!(ms >= 0.0 && ms.is_finite(), "floor-ms must be >= 0");
                    scn.train.compute_floor = Duration::from_secs_f64(ms / 1000.0);
                }
                "eval-ms" => {
                    let ms: f64 = v.parse().map_err(|_| num("eval-ms"))?;
                    anyhow::ensure!(ms > 0.0 && ms.is_finite(), "eval-ms must be > 0");
                    scn.train.eval_interval = Duration::from_secs_f64(ms / 1000.0);
                }
                "delay-frac" => {
                    scn.train.delay.affected_fraction =
                        v.parse().map_err(|_| num("delay-frac"))?
                }
                "delay-mean" => scn.train.delay.mean = v.parse().map_err(|_| num("delay-mean"))?,
                "delay-std" => scn.train.delay.std = v.parse().map_err(|_| num("delay-std"))?,
                "delay-dist" => scn.train.delay.dist = DelayDist::parse(v)?,
                "delay-regions" => {
                    scn.train.delay.regions = v.parse().map_err(|_| num("delay-regions"))?
                }
                "aggregate" => scn.train.aggregate = AggregateMode::parse(v)?,
                "partition" => scn.train.partition = crate::data::Partition::parse(v)?,
                "faults" => scn.faults = FaultPlan::parse(v)?,
                "compress" => scn.train.wire = WireFormat::parse(v)?,
                "elastic" => {
                    scn.train.elastic = match v {
                        "on" | "true" | "1" => true,
                        "off" | "false" | "0" => false,
                        _ => anyhow::bail!("bad elastic `{v}` in `{tok}` (on|off)"),
                    }
                }
                "quorum" => {
                    scn.train.min_quorum = v.parse().map_err(|_| num("quorum"))?;
                    anyhow::ensure!(scn.train.min_quorum >= 1, "quorum must be >= 1");
                }
                _ => anyhow::bail!("unknown scenario key `{k}` in `{tok}`"),
            }
        }
        scn.validate()?;
        Ok(scn)
    }

    /// Sanity checks shared by `parse` and `Simulation::new`.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.train.workers >= 1, "scenario needs at least 1 worker");
        anyhow::ensure!(
            !self.train.duration.is_zero(),
            "training budget must be > 0"
        );
        anyhow::ensure!(
            self.grad_time >= Duration::from_micros(1),
            "grad time below 1µs would flood the event queue"
        );
        anyhow::ensure!(
            !self.train.eval_interval.is_zero(),
            "eval interval must be > 0"
        );
        anyhow::ensure!(self.train.min_quorum >= 1, "quorum must be >= 1");
        // Mirrors trainer::validate_config: the robust estimators need a
        // round of retained rows to trim across, which async never forms.
        anyhow::ensure!(
            !(self.train.aggregate.retains_rows()
                && matches!(self.train.policy, Policy::Async)),
            "aggregate={} needs a buffering policy (sync or hybrid): async applies \
             each gradient on arrival, so there is no round to trim across",
            self.train.aggregate
        );
        if self.faults.has_membership() {
            anyhow::ensure!(
                self.train.elastic,
                "join/leave fault clauses require elastic=on \
                 (static membership has no live set to renormalize)"
            );
        }
        // Joiners take fresh ids after the launch complement, so every
        // worker-naming clause may address launch workers and joiners.
        let slots = self.train.workers + self.faults.total_joiners();
        if self.train.elastic {
            anyhow::ensure!(
                self.train.min_quorum <= slots,
                "quorum={} can never be met: the scenario has only {slots} worker slots \
                 ({} at launch + {} joiners) — the barrier would stall forever",
                self.train.min_quorum,
                self.train.workers,
                self.faults.total_joiners()
            );
        }
        if let Some(w) = self.faults.max_worker() {
            anyhow::ensure!(
                w < slots,
                "fault names worker {w} but the scenario has {slots} worker slots \
                 ({} at launch + {} joiners)",
                self.train.workers,
                self.faults.total_joiners()
            );
        }
        if let Some(s) = self.faults.max_shard() {
            anyhow::ensure!(
                s < self.train.shards.max(1),
                "fault names shard {s} but the scenario has {} shards",
                self.train.shards.max(1)
            );
        }
        Ok(())
    }
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let t = &self.train;
        write!(
            f,
            "workers={} shards={} policy={} secs={} seed={} lr={} grad-ms={} eval-ms={}",
            t.workers,
            t.shards,
            t.policy,
            t.duration.as_secs_f64(),
            t.seed,
            t.lr,
            self.grad_time.as_secs_f64() * 1000.0,
            t.eval_interval.as_secs_f64() * 1000.0,
        )?;
        if let Some(k) = t.k_max {
            write!(f, " kmax={k}")?;
        }
        if let Some(n) = t.steps {
            write!(f, " steps={n}")?;
        }
        if !t.compute_floor.is_zero() {
            write!(f, " floor-ms={}", t.compute_floor.as_secs_f64() * 1000.0)?;
        }
        if t.delay.affected_fraction != 0.0 || t.delay.mean != 0.0 || t.delay.std != 0.0 {
            write!(
                f,
                " delay-frac={} delay-mean={} delay-std={}",
                t.delay.affected_fraction, t.delay.mean, t.delay.std
            )?;
        }
        if t.delay.dist != DelayDist::Normal {
            write!(f, " delay-dist={}", t.delay.dist)?;
        }
        if t.delay.regions != 0 {
            write!(f, " delay-regions={}", t.delay.regions)?;
        }
        if !t.aggregate.is_mean() {
            write!(f, " aggregate={}", t.aggregate)?;
        }
        if !t.partition.is_iid() {
            write!(f, " partition={}", t.partition)?;
        }
        if !t.wire.is_dense() {
            write!(f, " compress={}", t.wire)?;
        }
        if t.elastic {
            write!(f, " elastic=on")?;
        }
        if t.min_quorum != 1 {
            write!(f, " quorum={}", t.min_quorum)?;
        }
        if !self.faults.is_empty() {
            write!(f, " faults={}", self.faults)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_headline_example() {
        let s = Scenario::parse("workers=8 shards=2 policy=hybrid:step:50 secs=10 faults=crash:3@5")
            .unwrap();
        assert_eq!(s.train.workers, 8);
        assert_eq!(s.train.shards, 2);
        assert_eq!(
            s.train.policy,
            Policy::Hybrid {
                schedule: Schedule::Step { step: 50 },
                strict: false
            }
        );
        assert_eq!(s.train.duration, Duration::from_secs(10));
        assert_eq!(s.faults.specs.len(), 1);
    }

    #[test]
    fn display_parse_roundtrip() {
        let spec = "workers=4 shards=3 policy=hybrid-strict:const:4 secs=2.5 seed=9 lr=0.1 \
                    grad-ms=2.5 floor-ms=20 eval-ms=250 kmax=3 steps=40 delay-frac=0.5 \
                    delay-mean=0 delay-std=0.25 compress=topk:0.01 \
                    faults=crash:1@1,stall:2@0.5..0.75";
        let a = Scenario::parse(spec).unwrap();
        assert_eq!(a.train.steps, Some(40));
        let b = Scenario::parse(&a.to_string()).unwrap();
        assert_eq!(a.train.workers, b.train.workers);
        assert_eq!(a.train.shards, b.train.shards);
        assert_eq!(a.train.policy, b.train.policy);
        assert_eq!(a.train.duration, b.train.duration);
        assert_eq!(a.train.seed, b.train.seed);
        assert_eq!(a.train.lr, b.train.lr);
        assert_eq!(a.train.k_max, b.train.k_max);
        assert_eq!(a.train.steps, b.train.steps);
        assert_eq!(a.train.delay, b.train.delay);
        assert_eq!(a.train.compute_floor, b.train.compute_floor);
        assert_eq!(a.grad_time, b.grad_time);
        assert_eq!(a.faults, b.faults);
        assert_eq!(a.train.wire, b.train.wire);
        assert_eq!(a.train.wire.to_string(), "topk:0.01");
    }

    #[test]
    fn compress_clause_parses_every_format_and_defaults_dense() {
        use crate::coordinator::compress::KSpec;
        assert!(Scenario::parse("").unwrap().train.wire.is_dense());
        // dense is the default, so Display omits the clause entirely
        assert!(!Scenario::parse("compress=dense")
            .unwrap()
            .to_string()
            .contains("compress="));
        let s = Scenario::parse("compress=topk+int8:64").unwrap();
        assert_eq!(s.train.wire, WireFormat::TopKInt8(KSpec::Count(64)));
        assert_eq!(
            Scenario::parse("compress=int8").unwrap().train.wire,
            WireFormat::Int8
        );
        assert!(Scenario::parse("compress=zip").is_err());
    }

    #[test]
    fn parse_rejects_bad_input() {
        for bad in [
            "workers",              // not key=value
            "workers=x",            // bad number
            "bogus=1",              // unknown key
            "secs=0",               // empty budget
            "grad-ms=0",            // event-queue flood
            "workers=2 faults=crash:5@1", // fault out of range
            "shards=2 faults=stall:2@1..2", // shard out of range
            "policy=nope",
            "elastic=maybe",        // not on|off
            "quorum=0",             // quorum floor below 1
            "quorum=x",
            // membership churn without elastic=on
            "workers=2 faults=join:+1@1",
            "workers=2 faults=leave:0@1",
            // leave names a slot beyond launch workers + joiners
            "workers=2 elastic=on faults=join:+1@1,leave:3@2",
            // a quorum no membership could ever satisfy (barrier stalls)
            "workers=2 elastic=on quorum=3",
            "workers=2 elastic=on quorum=4 faults=join:+1@1",
        ] {
            assert!(Scenario::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn elastic_keys_parse_and_roundtrip() {
        let s = Scenario::parse(
            "workers=3 elastic=on quorum=2 secs=4 faults=leave:1@1,join:+2@2,crash:4@3",
        )
        .unwrap();
        assert!(s.train.elastic);
        assert_eq!(s.train.min_quorum, 2);
        // crash:4 addresses a joiner slot (3 launch + 2 joiners = 5 slots)
        assert_eq!(s.faults.total_joiners(), 2);
        let logged = s.to_string();
        assert!(logged.contains("elastic=on"), "{logged}");
        assert!(logged.contains("quorum=2"), "{logged}");
        let replay = Scenario::parse(&logged).unwrap();
        assert_eq!(replay.train.elastic, s.train.elastic);
        assert_eq!(replay.train.min_quorum, s.train.min_quorum);
        assert_eq!(replay.faults, s.faults);
        // defaults stay silent: no elastic/quorum clutter in static lines
        let plain = Scenario::parse("workers=2").unwrap();
        assert!(!plain.train.elastic);
        assert_eq!(plain.train.min_quorum, 1);
        let line = plain.to_string();
        assert!(!line.contains("elastic="), "{line}");
        assert!(!line.contains("quorum="), "{line}");
    }

    #[test]
    fn robustness_keys_parse_and_roundtrip() {
        let s = Scenario::parse(
            "workers=8 policy=sync aggregate=trimmed:0.25 partition=dirichlet:0.3 \
             delay-frac=1 delay-mean=-2 delay-std=0.5 delay-dist=lognormal delay-regions=3 \
             faults=byz-scale:7:10@1",
        )
        .unwrap();
        assert_eq!(s.train.aggregate, AggregateMode::Trimmed(0.25));
        assert_eq!(s.train.partition, crate::data::Partition::Dirichlet(0.3));
        assert_eq!(s.train.delay.dist, DelayDist::LogNormal);
        assert_eq!(s.train.delay.regions, 3);
        assert!(s.faults.has_byzantine());
        let logged = s.to_string();
        assert!(logged.contains("aggregate=trimmed:0.25"), "{logged}");
        assert!(logged.contains("partition=dirichlet:0.3"), "{logged}");
        assert!(logged.contains("delay-dist=lognormal"), "{logged}");
        assert!(logged.contains("delay-regions=3"), "{logged}");
        assert!(logged.contains("faults=byz-scale:7:10@1"), "{logged}");
        let replay = Scenario::parse(&logged).unwrap();
        assert_eq!(replay.train.aggregate, s.train.aggregate);
        assert_eq!(replay.train.partition, s.train.partition);
        assert_eq!(replay.train.delay, s.train.delay);
        assert_eq!(replay.faults, s.faults);
        // Defaults stay silent: a plain scenario logs none of the new keys.
        let plain = Scenario::parse("workers=2").unwrap().to_string();
        for key in ["aggregate=", "partition=", "delay-dist=", "delay-regions="] {
            assert!(!plain.contains(key), "{plain}");
        }
    }

    #[test]
    fn robustness_keys_reject_bad_input() {
        for bad in [
            "aggregate=mode7",                  // unknown mode
            "aggregate=trimmed:0.5",            // trim fraction out of range
            "aggregate=clip:0",                 // clip radius must be > 0
            "partition=dirichlet:0",            // alpha must be > 0
            "partition=sorted",                 // unknown scheme
            "delay-dist=pareto",                // unknown family
            "delay-regions=x",                  // not a count
            "workers=4 faults=byz-nan:4@1",     // byz names worker out of range
            // robust estimators need a round to trim across
            "policy=async aggregate=median",
            "policy=async aggregate=trimmed:0.1",
        ] {
            assert!(Scenario::parse(bad).is_err(), "`{bad}` should not parse");
        }
        // clip composes with async: it acts per contribution, not per round.
        assert!(Scenario::parse("policy=async aggregate=clip:1").is_ok());
    }

    #[test]
    fn defaults_are_fault_free() {
        let s = Scenario::parse("").unwrap();
        assert!(s.faults.is_empty());
        assert_eq!(s.train.delay, DelayModel::none());
        assert_eq!(s.train.workers, 8);
        assert_eq!(s.grad_time, Duration::from_millis(5));
    }
}
