//! The network transport subsystem: how gradients and parameters move
//! between workers and the parameter server when they are **separate
//! processes** — and the trait that keeps the in-process path identical to
//! what it always was.
//!
//! Layering, bottom-up:
//! - [`frame`] — a length-prefixed, versioned binary frame codec with a
//!   hand-rolled CRC32 integrity check (std-only, no crates.io, consistent
//!   with the repo's vendored-shim policy). Typed errors for truncated /
//!   corrupt / version-mismatched frames; encode/decode into reusable
//!   buffers.
//! - [`msg`] — the control-plane message set (`Hello`, `Welcome`,
//!   `SubmitGrad`, `GradAck`, `SnapshotRequest`, `SnapshotSlice`,
//!   `Heartbeat`, `Shutdown`, plus the elastic-membership pair `Leave` /
//!   `Evict` — DESIGN.md §2.7) with exhaustive roundtrip encode/decode.
//!   Gradient payloads travel shard-local in any
//!   [`crate::coordinator::compress::WireFormat`].
//! - [`Transport`] — the worker's view of the parameter server: submit a
//!   shard's gradient, receive O(1) version-token replies, refresh a
//!   shard's parameter slice. Two implementations:
//!   - [`InProcTransport`] wraps the existing channels + snapshot cells.
//!     It is the default and is *bitwise-identical* to the pre-transport
//!     protocol — the threaded and simulated paths do not change.
//!   - [`tcp::TcpTransport`] speaks the frame protocol over `std::net`
//!     with reconnect-with-backoff and heartbeat-based half-open
//!     detection. Byte counters on this path are measured at true frame
//!     granularity (headers + payload).
//! - [`reactor::TcpFrontend`] — the server side, and the default: one
//!   event-driven reactor thread (nonblocking sockets, a `poll(2)` shim,
//!   vectored coalesced writes, a deadline heap for heartbeats/liveness)
//!   owns the acceptor and every connection and bridges remote workers
//!   onto the same `run_shard` channels the in-process stack uses. The
//!   legacy [`tcp::ThreadedFrontend`] (reader/writer/reply-pump threads
//!   per connection) speaks the identical wire protocol and remains as
//!   the scaling baseline; [`Frontend`] / [`FrontendKind`] select between
//!   them (`serve --frontend reactor|threaded`).
//! - [`loadgen`] — the connections-vs-throughput measurement harness
//!   behind `BENCH_transport.json`'s scaling curve.
//!
//! Frame layout, versioning rules, heartbeat/reconnect semantics and the
//! byte-accounting contract are documented in DESIGN.md §2.6; the reactor
//! architecture and its wire-bytes-identical invariant in §2.8.

pub mod frame;
pub mod loadgen;
pub mod msg;
pub mod reactor;
pub mod tcp;

pub use frame::{crc32, decode_frame, encode_frame_into, FrameError, FrameReader, FRAME_OVERHEAD};
pub use msg::{Msg, WireError};
pub use reactor::TcpFrontend;
pub use tcp::{FrontendStats, NetOptions, ThreadedFrontend, TcpTransport};

use crate::coordinator::params::SnapshotCell;
use crate::coordinator::server::{Reply, ShardEvent, ShardMsg, StatusBoard};
use crate::coordinator::shard::ShardLayout;
use crate::coordinator::worker::ShardEndpoints;
use crate::util::trace::TraceRing;
use std::fmt;
use std::net::TcpListener;
use std::sync::atomic::AtomicBool;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Which serving frontend `serve` runs (`--frontend reactor|threaded`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrontendKind {
    /// The event-driven single-thread reactor ([`reactor::TcpFrontend`]).
    /// The default.
    Reactor,
    /// The legacy three-threads-per-connection frontend
    /// ([`tcp::ThreadedFrontend`]) — the scaling-curve baseline.
    Threaded,
}

impl FrontendKind {
    pub fn parse(s: &str) -> anyhow::Result<FrontendKind> {
        match s {
            "reactor" => Ok(FrontendKind::Reactor),
            "threaded" => Ok(FrontendKind::Threaded),
            other => anyhow::bail!(
                "unknown frontend `{other}` (expected `reactor` or `threaded`)"
            ),
        }
    }
}

/// A running serving frontend of either kind. Both speak the identical
/// wire protocol over the same `run_shard` channels; only the scheduling
/// differs (see DESIGN.md §2.8).
pub enum Frontend {
    Reactor(reactor::TcpFrontend),
    Threaded(tcp::ThreadedFrontend),
}

impl Frontend {
    /// Start serving on `listener`. Arguments as the frontends' own
    /// `start`; see [`tcp::ThreadedFrontend::start`].
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        kind: FrontendKind,
        listener: TcpListener,
        layout: ShardLayout,
        grad_txs: Vec<Sender<ShardEvent>>,
        cells: Vec<Arc<SnapshotCell>>,
        reply_rxs: Vec<Receiver<Reply>>,
        delayed: Vec<bool>,
        stop: Arc<AtomicBool>,
        net: NetOptions,
        elastic: bool,
        status: Option<Arc<StatusBoard>>,
        trace: Option<Arc<TraceRing>>,
    ) -> std::io::Result<Frontend> {
        match kind {
            FrontendKind::Reactor => reactor::TcpFrontend::start(
                listener, layout, grad_txs, cells, reply_rxs, delayed, stop, net, elastic, status,
                trace,
            )
            .map(Frontend::Reactor),
            FrontendKind::Threaded => tcp::ThreadedFrontend::start(
                listener, layout, grad_txs, cells, reply_rxs, delayed, stop, net, elastic, status,
                trace,
            )
            .map(Frontend::Threaded),
        }
    }

    /// Workers currently connected.
    pub fn active_conns(&self) -> usize {
        match self {
            Frontend::Reactor(f) => f.active_conns(),
            Frontend::Threaded(f) => f.active_conns(),
        }
    }

    /// Workers that have ever completed an attach.
    pub fn ever_joined(&self) -> usize {
        match self {
            Frontend::Reactor(f) => f.ever_joined(),
            Frontend::Threaded(f) => f.ever_joined(),
        }
    }

    /// Gradient-plane byte counters.
    pub fn stats(&self) -> FrontendStats {
        match self {
            Frontend::Reactor(f) => f.stats(),
            Frontend::Threaded(f) => f.stats(),
        }
    }

    /// The reactor's reply-wakeup callback (acks leave within one loop
    /// iteration instead of a poll tick). `None` for the threaded
    /// frontend, whose blocking reply pumps need no wakeup.
    pub fn reply_notifier(&self) -> Option<Arc<dyn Fn(usize) + Send + Sync>> {
        match self {
            Frontend::Reactor(f) => Some(f.reply_notifier()),
            Frontend::Threaded(_) => None,
        }
    }

    /// Stop serving: workers receive `Shutdown`, connections close, the
    /// gradient senders release so the shard servers drain and exit.
    pub fn shutdown(self) -> FrontendStats {
        match self {
            Frontend::Reactor(f) => f.shutdown(),
            Frontend::Threaded(f) => f.shutdown(),
        }
    }
}

/// Assemble the read-only status document both frontends serve in reply
/// to [`Msg::StatusRequest`] (DESIGN.md §2.9). Everything here is read
/// from atomics or immutable config — the gradient plane is never
/// touched, so polling status cannot perturb a run's bitwise trace.
#[allow(clippy::too_many_arguments)]
pub(crate) fn render_status(
    frontend: &str,
    layout: &ShardLayout,
    slots: usize,
    active: usize,
    ever_joined: usize,
    grad_frame_bytes: u64,
    submissions: u64,
    uptime: Duration,
    status: Option<&StatusBoard>,
    trace: Option<&TraceRing>,
) -> String {
    use crate::util::json::Utf8JsonWriter;
    use std::sync::atomic::Ordering;
    let mut w = Utf8JsonWriter::new();
    w.begin_object();
    w.key("now_ms");
    w.num(
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as f64)
            .unwrap_or(0.0),
    );
    w.key("frontend");
    w.str(frontend);
    w.key("uptime_secs");
    w.num(uptime.as_secs_f64());
    w.key("workers");
    w.begin_object();
    w.key("slots");
    w.num(slots as f64);
    w.key("active");
    w.num(active as f64);
    w.key("ever_joined");
    w.num(ever_joined as f64);
    w.end_object();
    // Membership is global (every shard sees the same join/leave events);
    // shard 0's view stands for the run.
    let (live, epoch) = match status {
        Some(b) if !b.shards.is_empty() => (
            b.shards[0].live.load(Ordering::Relaxed),
            b.shards[0].epoch.load(Ordering::Relaxed),
        ),
        _ => (0, 0),
    };
    w.key("membership");
    w.begin_object();
    w.key("live");
    w.num(live as f64);
    w.key("epoch");
    w.num(epoch as f64);
    w.end_object();
    w.key("shards");
    w.begin_array();
    if let Some(board) = status {
        for (i, st) in board.shards.iter().enumerate() {
            w.begin_object();
            w.key("shard");
            w.num(i as f64);
            w.key("dim");
            w.num(layout.range(i).len() as f64);
            w.key("k");
            w.num(st.k.load(Ordering::Relaxed) as f64);
            w.key("buffered");
            w.num(st.buffered.load(Ordering::Relaxed) as f64);
            w.key("version");
            w.num(st.version.load(Ordering::Relaxed) as f64);
            // Snapshot-pool traffic: publish count and bytes copied. With
            // delta tracking the bytes grow with *dirty* blocks per
            // publish, not shard dim — the big-model memory gauge.
            w.key("snap_publishes");
            w.num(st.snap_publishes.load(Ordering::Relaxed) as f64);
            w.key("snap_bytes");
            w.num(st.snap_bytes.load(Ordering::Relaxed) as f64);
            w.end_object();
        }
    }
    w.end_array();
    // Process-level memory high-water mark (0 where /proc is absent).
    w.key("memory");
    w.begin_object();
    w.key("peak_rss_bytes");
    w.num(crate::coordinator::metrics::peak_rss_bytes() as f64);
    w.end_object();
    // Per-worker arrival/staleness gauges (shard 0's view; see
    // `WorkerStatus`). Omitted entirely when the board carries no worker
    // slots so pre-existing consumers see an unchanged document.
    if let Some(board) = status {
        if !board.workers.is_empty() {
            w.key("per_worker");
            w.begin_array();
            for (i, ws) in board.workers.iter().enumerate() {
                let grads = ws.grads.load(Ordering::Relaxed);
                w.begin_object();
                w.key("worker");
                w.num(i as f64);
                w.key("grads");
                w.num(grads as f64);
                w.key("rejected");
                w.num(ws.rejected.load(Ordering::Relaxed) as f64);
                w.key("staleness_mean");
                w.num(if grads > 0 {
                    ws.stale_sum.load(Ordering::Relaxed) as f64 / grads as f64
                } else {
                    0.0
                });
                w.key("staleness_max");
                w.num(ws.stale_max.load(Ordering::Relaxed) as f64);
                // Log2 buckets: 0, 1, 2-3, 4-7, 8-15, >=16.
                w.key("staleness_hist");
                w.begin_array();
                for b in &ws.stale_hist {
                    w.num(b.load(Ordering::Relaxed) as f64);
                }
                w.end_array();
                w.end_object();
            }
            w.end_array();
        }
    }
    w.key("bytes");
    w.begin_object();
    // Lifetime total (frame granularity, headers included).
    w.key("grad_frame_bytes");
    w.num(grad_frame_bytes as f64);
    w.key("submissions");
    w.num(submissions as f64);
    // `bytes_per_sec` is a sliding-window rate over ~the last 5 s of
    // samples (each render records one, throttled — a 250 ms follower or
    // poller keeps the window live). The lifetime mean is the fallback
    // before two samples span the window, and stays available under its
    // own key: dividing the lifetime total by the whole uptime reports a
    // long-dead transfer rate on any run with idle phases.
    let secs = uptime.as_secs_f64();
    let lifetime = if secs > 0.0 {
        grad_frame_bytes as f64 / secs
    } else {
        0.0
    };
    let windowed = status.and_then(|b| {
        b.push_rate_sample(uptime, grad_frame_bytes);
        b.window_bytes_per_sec(uptime)
    });
    w.key("bytes_per_sec");
    w.num(windowed.unwrap_or(lifetime));
    w.key("bytes_per_sec_lifetime");
    w.num(lifetime);
    w.end_object();
    // Per-stage gradient-lifecycle latency summaries (p50/p99 from the
    // flight recorder's log2 histograms) when the run is traced.
    if let Some(ring) = trace {
        w.key("stages");
        ring.write_stages_json(&mut w);
    }
    w.end_object();
    w.finish()
}

/// Why a transport operation did not complete.
#[derive(Debug)]
pub enum TransportError {
    /// `recv_reply` saw nothing within the timeout. Retryable; callers
    /// check their stop flag and wait again (exactly like the channel
    /// protocol's `RecvTimeoutError::Timeout`).
    Timeout,
    /// The connection was lost and re-established. Replies and snapshots
    /// in flight at the loss are gone: the caller must abandon its current
    /// round, refresh every shard slice and resume submitting. Never
    /// produced by [`InProcTransport`].
    Reconnected,
    /// The transport is permanently gone (server shut down, reconnect
    /// budget exhausted, or the in-process channels closed).
    Closed(String),
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Timeout => write!(f, "transport timeout"),
            TransportError::Reconnected => write!(f, "transport reconnected; round lost"),
            TransportError::Closed(why) => write!(f, "transport closed: {why}"),
        }
    }
}

impl std::error::Error for TransportError {}

/// A worker's connection to the (possibly remote) sharded parameter
/// server. The contract mirrors the channel protocol `run_worker` always
/// spoke: fan one submission out to all `S` shards, await one reply per
/// shard, refresh only the slices whose version changed.
pub trait Transport: Send {
    /// The shard layout of the parameter server this transport reaches.
    fn layout(&self) -> &ShardLayout;

    /// Send one shard's portion of a gradient submission.
    fn submit(&mut self, shard: usize, msg: ShardMsg) -> Result<(), TransportError>;

    /// Block for the next shard reply, up to `timeout`.
    fn recv_reply(&mut self, timeout: Duration) -> Result<Reply, TransportError>;

    /// Copy shard `shard`'s current parameters into `out` (sized to the
    /// shard's range); returns the version of the copied snapshot.
    fn refresh(&mut self, shard: usize, out: &mut [f32]) -> Result<u64, TransportError>;

    /// Frame-granularity (bytes actually on the wire, headers included)
    /// counters, when this transport measures them: `(sent, received)`.
    /// `None` (the in-process default) keeps the caller's logical payload
    /// accounting, preserving the pre-transport byte semantics bitwise.
    fn wire_counters(&self) -> Option<(u64, u64)> {
        None
    }

    /// Snapshot-response payload bytes this transport consumed serving
    /// refreshes, when it measures them. `None` (the in-process default)
    /// keeps the caller's logical 4 B × slice-length accounting; TCP
    /// reports actual payloads, where the delta protocol ships only dirty
    /// blocks instead of whole slices.
    fn refresh_wire_bytes(&self) -> Option<u64> {
        None
    }
}

/// The default transport: the in-process channel protocol, verbatim.
/// `submit` is a channel send of the same `ShardMsg` (zero-copy `Arc`
/// fan-out preserved), `recv_reply` the same `recv_timeout`, `refresh` the
/// same snapshot-cell pointer read + memcpy — so threaded runs with this
/// transport are bitwise-identical to the pre-transport stack
/// (golden-trace tested in `tests/transport_integration.rs`).
pub struct InProcTransport {
    endpoints: ShardEndpoints,
    reply_rx: Receiver<Reply>,
}

impl InProcTransport {
    pub fn new(endpoints: ShardEndpoints, reply_rx: Receiver<Reply>) -> InProcTransport {
        debug_assert_eq!(endpoints.grad_txs.len(), endpoints.layout.shards());
        debug_assert_eq!(endpoints.cells.len(), endpoints.layout.shards());
        InProcTransport {
            endpoints,
            reply_rx,
        }
    }
}

impl Transport for InProcTransport {
    fn layout(&self) -> &ShardLayout {
        &self.endpoints.layout
    }

    fn submit(&mut self, shard: usize, msg: ShardMsg) -> Result<(), TransportError> {
        self.endpoints.grad_txs[shard]
            .send(ShardEvent::Grad(msg))
            .map_err(|_| TransportError::Closed("shard server channel closed".into()))
    }

    fn recv_reply(&mut self, timeout: Duration) -> Result<Reply, TransportError> {
        match self.reply_rx.recv_timeout(timeout) {
            Ok(r) => Ok(r),
            Err(RecvTimeoutError::Timeout) => Err(TransportError::Timeout),
            Err(RecvTimeoutError::Disconnected) => {
                Err(TransportError::Closed("reply channel closed".into()))
            }
        }
    }

    fn refresh(&mut self, shard: usize, out: &mut [f32]) -> Result<u64, TransportError> {
        let snap = self.endpoints.cells[shard].load();
        snap.copy_to(out);
        Ok(snap.version)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::compress::ShardGrad;
    use crate::coordinator::params::SnapshotCell;
    use std::sync::mpsc;
    use std::sync::Arc;

    #[test]
    fn inproc_transport_is_the_channel_protocol() {
        let layout = ShardLayout::new(4, 2);
        let (gtx0, grx0) = mpsc::channel::<ShardEvent>();
        let (gtx1, grx1) = mpsc::channel::<ShardEvent>();
        let (rtx, rrx) = mpsc::channel::<Reply>();
        let cells = vec![
            Arc::new(SnapshotCell::new(vec![1.0, 2.0])),
            Arc::new(SnapshotCell::new(vec![3.0, 4.0])),
        ];
        let endpoints = ShardEndpoints {
            layout,
            grad_txs: vec![gtx0, gtx1],
            cells,
        };
        let mut t = InProcTransport::new(endpoints, rrx);
        assert_eq!(t.layout().shards(), 2);
        // submit routes to the right shard channel, payload untouched
        let shared = Arc::new(vec![9.0f32; 4]);
        t.submit(
            1,
            ShardMsg {
                worker: 0,
                base_version: 7,
                loss: 0.5,
                grad: ShardGrad::Dense(Arc::clone(&shared)),
                enq_ns: 0,
            },
        )
        .unwrap();
        assert!(grx0.try_recv().is_err());
        let got = match grx1.try_recv().unwrap() {
            ShardEvent::Grad(m) => m,
            _ => panic!("expected a gradient event"),
        };
        assert_eq!(got.base_version, 7);
        drop(got);
        assert_eq!(Arc::strong_count(&shared), 1);
        // replies pass through; timeout is typed
        rtx.send(Reply::Unchanged { shard: 0 }).unwrap();
        assert!(matches!(
            t.recv_reply(Duration::from_millis(100)),
            Ok(Reply::Unchanged { shard: 0 })
        ));
        assert!(matches!(
            t.recv_reply(Duration::from_millis(10)),
            Err(TransportError::Timeout)
        ));
        // refresh copies the cell's snapshot
        let mut buf = [0.0f32; 2];
        assert_eq!(t.refresh(0, &mut buf).unwrap(), 0);
        assert_eq!(buf, [1.0, 2.0]);
        // no frame-granularity counters on the in-process path
        assert!(t.wire_counters().is_none());
        // dropping the reply sender surfaces as Closed
        drop(rtx);
        assert!(matches!(
            t.recv_reply(Duration::from_millis(10)),
            Err(TransportError::Closed(_))
        ));
        // dropping a shard receiver surfaces as Closed on submit
        drop(grx0);
        let err = t.submit(
            0,
            ShardMsg {
                worker: 0,
                base_version: 0,
                loss: 0.0,
                grad: ShardGrad::Dense(Arc::new(vec![0.0; 4])),
                enq_ns: 0,
            },
        );
        assert!(matches!(err, Err(TransportError::Closed(_))));
    }

    #[test]
    fn status_document_carries_per_worker_staleness() {
        use std::sync::atomic::Ordering;
        let layout = ShardLayout::new(4, 1);
        let board = StatusBoard::with_workers(1, 2);
        let w1 = &board.workers[1];
        w1.grads.store(4, Ordering::Relaxed);
        w1.rejected.store(1, Ordering::Relaxed);
        w1.stale_sum.store(6, Ordering::Relaxed);
        w1.stale_max.store(3, Ordering::Relaxed);
        w1.stale_hist[0].store(2, Ordering::Relaxed);
        w1.stale_hist[2].store(2, Ordering::Relaxed);
        let doc = render_status(
            "test",
            &layout,
            2,
            2,
            2,
            0,
            0,
            Duration::from_secs(1),
            Some(&board),
            None,
        );
        assert!(doc.contains("\"per_worker\":["));
        // Worker 0 never submitted: zeros, mean guarded against 0/0.
        assert!(doc.contains("\"worker\":0,\"grads\":0,\"rejected\":0,\"staleness_mean\":0"));
        assert!(doc.contains(
            "\"worker\":1,\"grads\":4,\"rejected\":1,\"staleness_mean\":1.5,\
             \"staleness_max\":3,\"staleness_hist\":[2,0,2,0,0,0]"
        ));
        // A board without worker slots omits the section entirely.
        let bare = StatusBoard::new(1);
        let doc = render_status(
            "test",
            &layout,
            2,
            2,
            2,
            0,
            0,
            Duration::from_secs(1),
            Some(&bare),
            None,
        );
        assert!(!doc.contains("per_worker"));
    }

    #[test]
    fn bytes_per_sec_windows_over_recent_samples_not_the_whole_uptime() {
        use crate::util::json::scan_path;
        let layout = ShardLayout::new(4, 1);
        let board = StatusBoard::new(1);
        let doc_at = |secs: f64, bytes: u64| {
            render_status(
                "test",
                &layout,
                1,
                1,
                1,
                bytes,
                0,
                Duration::from_secs_f64(secs),
                Some(&board),
                None,
            )
        };
        let rate = |doc: &str| {
            scan_path(doc, "bytes.bytes_per_sec")
                .unwrap()
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // First render: one sample — falls back to the lifetime mean.
        let first = doc_at(100.0, 1_000_000);
        assert_eq!(rate(&first), 10_000.0);
        assert_eq!(
            scan_path(&first, "bytes.bytes_per_sec_lifetime")
                .unwrap()
                .unwrap()
                .as_f64()
                .unwrap(),
            10_000.0
        );
        assert_eq!(
            scan_path(&first, "bytes.grad_frame_bytes")
                .unwrap()
                .unwrap()
                .as_f64()
                .unwrap(),
            1_000_000.0
        );
        // 2 s later, 2 MB more moved: the window reports ~1 MB/s while the
        // lifetime mean (3 MB over 102 s) would claim ~30 KB/s.
        let doc = doc_at(102.0, 3_000_000);
        assert_eq!(rate(&doc), 1_000_000.0);
        // An idle stretch beyond the window drops back to the lifetime
        // mean (the stale samples age out rather than reporting the old
        // burst forever).
        let doc = doc_at(200.0, 3_000_000);
        assert_eq!(rate(&doc), 15_000.0);
        // Untraced runs carry no stages section; traced runs do.
        assert!(!doc.contains("\"stages\""));
        let ring = crate::util::trace::TraceRing::new(64);
        ring.span(crate::util::trace::Stage::Apply, 0, 0, 0, 2_000_000, 1, 1);
        let traced = render_status(
            "test",
            &layout,
            1,
            1,
            1,
            0,
            0,
            Duration::from_secs(1),
            None,
            Some(&ring),
        );
        assert!(traced.contains("\"stages\":{\"apply\":{\"count\":1"));
    }
}
