//! TCP transport over `std::net`: blocking reader/writer threads per
//! connection, reconnect-with-backoff, and half-open detection via
//! heartbeat timeout.
//!
//! Client side ([`TcpTransport`]): one socket to the server. The worker
//! thread writes submission frames directly (serialized with the heartbeat
//! ticker by a write mutex); a reader thread decodes incoming frames and
//! routes `GradAck`s and `SnapshotSlice`s onto internal channels the
//! [`super::Transport`] methods consume. If the socket dies — an I/O error,
//! a peer close, or silence past the heartbeat timeout (the half-open case:
//! TCP happily buffers into a black hole for minutes) — the transport
//! redials with exponential backoff, re-attaches under its assigned worker
//! id, and surfaces [`super::TransportError::Reconnected`] so the worker
//! loop abandons the lost round and refreshes.
//!
//! Server side ([`ThreadedFrontend`]): a non-blocking acceptor plus three
//! threads per connection (frame reader, frame writer, reply pump) that
//! bridge a remote worker onto the *same* `run_shard` channels the
//! in-process stack uses — the shard servers cannot tell local and remote
//! workers apart. Worker slots are fixed at `serve` time (the aggregation
//! policies need the worker count); a reconnecting worker re-attaches to
//! its slot once the dead connection's reply pump has returned the slot's
//! reply channel.
//!
//! The threaded frontend is the legacy serving path, kept as the baseline
//! for the connections-vs-throughput comparison (`--frontend threaded`).
//! `serve` defaults to the event-driven reactor ([`super::reactor`]),
//! which speaks the identical wire protocol from a single thread.
//!
//! Byte accounting: both ends count **submission frames at frame
//! granularity** (frame header + message + CRC). Control traffic
//! (hello/welcome, heartbeats, snapshot requests/slices) is excluded by
//! design so equal-bandwidth comparisons stay deterministic and comparable
//! with the in-process counters — see DESIGN.md §2.6 for the exact
//! per-submission overhead formula.

use super::frame::{encode_frame_into, FrameReader, FRAME_OVERHEAD, MAX_PAYLOAD};
use super::msg::{
    apply_snapshot_delta, encode_submit_into, snapshot_response_msgs, snapshot_slice_bytes, Msg,
    SNAP_DELTA_HEADER_BYTES, WORKER_UNASSIGNED,
};
use super::{Transport, TransportError};
use crate::coordinator::compress::ShardGrad;
use crate::coordinator::params::SnapshotCell;
use crate::coordinator::server::{Reply, ShardEvent, ShardMsg, StatusBoard};
use crate::coordinator::shard::ShardLayout;
use crate::log_warn;
use crate::util::trace::{Stage, TraceRing};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Socket-poll granularity: blocking reads wake this often to check stop /
/// liveness flags, so shutdown latency is bounded by it.
const POLL: Duration = Duration::from_millis(25);

/// Network tuning knobs shared by client and server.
#[derive(Clone, Debug)]
pub struct NetOptions {
    /// How often an idle peer emits a `Heartbeat`.
    pub hb_interval: Duration,
    /// Silence longer than this marks the connection half-open and dead.
    /// Must be comfortably larger than `hb_interval`.
    pub hb_timeout: Duration,
    /// Total dial budget (including exponential backoff) per connect or
    /// reconnect attempt sequence.
    pub connect_timeout: Duration,
    /// How many full redial sequences a lost connection is granted before
    /// the transport reports itself closed.
    pub reconnect_attempts: u32,
    /// Largest legacy full-`SnapshotSlice` payload (bytes) a refresh reply
    /// may use; bigger slices — and every half-precision snapshot — are
    /// served as chunked `SnapshotDelta` frames instead. Defaults to the
    /// frame limit; tests shrink it to force chunking at small dims.
    pub snap_full_max: usize,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions {
            hb_interval: Duration::from_millis(500),
            hb_timeout: Duration::from_secs(5),
            connect_timeout: Duration::from_secs(10),
            reconnect_attempts: 2,
            snap_full_max: MAX_PAYLOAD,
        }
    }
}

// ---------------------------------------------------------------------------
// shared plumbing
// ---------------------------------------------------------------------------

/// Liveness state shared by one connection's threads.
struct ConnState {
    dead: AtomicBool,
    /// The peer sent a clean `Shutdown` (reconnecting is pointless).
    shutdown: AtomicBool,
    /// Milliseconds since `epoch` of the last received byte.
    last_rx_ms: AtomicU64,
    epoch: Instant,
    /// All bytes received on this connection, frame granularity.
    bytes_received: AtomicU64,
}

impl ConnState {
    fn new() -> Arc<ConnState> {
        Arc::new(ConnState {
            dead: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            last_rx_ms: AtomicU64::new(0),
            epoch: Instant::now(),
            bytes_received: AtomicU64::new(0),
        })
    }

    fn mark_rx(&self) {
        self.last_rx_ms
            .store(self.epoch.elapsed().as_millis() as u64, Ordering::Relaxed);
    }

    fn silent_for(&self) -> Duration {
        let now = self.epoch.elapsed().as_millis() as u64;
        Duration::from_millis(now.saturating_sub(self.last_rx_ms.load(Ordering::Relaxed)))
    }
}

/// Write one frame carrying `msg` to `stream` under the write lock,
/// reusing the caller's scratch buffers.
fn write_msg(
    stream: &Mutex<TcpStream>,
    msg: &Msg,
    msg_buf: &mut Vec<u8>,
    frame_buf: &mut Vec<u8>,
) -> std::io::Result<usize> {
    msg.encode_into(msg_buf)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
    frame_buf.clear();
    encode_frame_into(msg_buf, frame_buf);
    let mut s = stream.lock().unwrap();
    s.write_all(frame_buf)?;
    Ok(frame_buf.len())
}

/// Read frames until one complete message arrives or `deadline` passes
/// (handshake path — the steady state uses a dedicated reader thread).
/// `pub(crate)` so the reactor frontend's tests can drive raw handshakes.
pub(crate) fn read_msg_blocking(
    stream: &mut TcpStream,
    reader: &mut FrameReader,
    payload: &mut Vec<u8>,
    deadline: Instant,
) -> anyhow::Result<Msg> {
    let mut chunk = [0u8; 16 * 1024];
    loop {
        if reader.next_frame(payload)? {
            return Ok(Msg::decode(payload)?);
        }
        if Instant::now() >= deadline {
            anyhow::bail!("timed out waiting for a handshake message");
        }
        stream.set_read_timeout(Some(POLL))?;
        match stream.read(&mut chunk) {
            Ok(0) => anyhow::bail!("peer closed during handshake"),
            Ok(n) => reader.feed(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// Dial with exponential backoff until `budget` elapses.
fn dial_with_backoff(addr: &str, budget: Duration) -> anyhow::Result<TcpStream> {
    let deadline = Instant::now() + budget;
    let mut backoff = Duration::from_millis(50);
    let mut last_err: Option<std::io::Error> = None;
    loop {
        // Re-resolve each attempt (the server may come up after us).
        match addr.to_socket_addrs() {
            Ok(mut addrs) => {
                if let Some(sa) = addrs.next() {
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        break;
                    }
                    match TcpStream::connect_timeout(&sa, remaining.min(Duration::from_secs(2))) {
                        Ok(s) => return Ok(s),
                        Err(e) => last_err = Some(e),
                    }
                } else {
                    anyhow::bail!("address `{addr}` resolved to nothing");
                }
            }
            Err(e) => last_err = Some(e),
        }
        if Instant::now() + backoff >= deadline {
            break;
        }
        std::thread::sleep(backoff);
        backoff = (backoff * 2).min(Duration::from_secs(2));
    }
    match last_err {
        Some(e) => Err(anyhow::anyhow!("could not connect to {addr}: {e}")),
        None => Err(anyhow::anyhow!("could not connect to {addr}: dial budget elapsed")),
    }
}

/// Dial `addr`, send one `StatusRequest`, and return the server's status
/// document (a JSON string — the transport behind `hybrid-sgd status`).
/// Answered from the handshake phase of either frontend, so the probe
/// never consumes a worker slot and never touches the gradient plane.
pub fn query_status(addr: &str, net: &NetOptions) -> anyhow::Result<String> {
    let mut stream = dial_with_backoff(addr, net.connect_timeout)?;
    stream.set_nodelay(true).ok();
    let mut msg_buf = Vec::new();
    let mut frame_buf = Vec::new();
    Msg::StatusRequest.encode_into(&mut msg_buf)?;
    encode_frame_into(&msg_buf, &mut frame_buf);
    stream.write_all(&frame_buf)?;
    let mut reader = FrameReader::new();
    let mut payload = Vec::new();
    let deadline = Instant::now() + net.hb_timeout;
    loop {
        match read_msg_blocking(&mut stream, &mut reader, &mut payload, deadline)? {
            Msg::Status { json } => return Ok(json),
            Msg::Heartbeat { .. } => {} // idle server chatter: keep waiting
            other => anyhow::bail!("expected Status, got {other:?}"),
        }
    }
}

/// Dial `addr`, subscribe to status pushes at `interval_ms`, and hand
/// each `StatusDelta` to `on_delta` until it returns `false`, the server
/// shuts down, or the stream dies (the transport behind
/// `hybrid-sgd status --follow`). Sends heartbeats so the server's
/// liveness check keeps the follower alive between deltas.
pub fn follow_status(
    addr: &str,
    net: &NetOptions,
    interval_ms: u32,
    mut on_delta: impl FnMut(u64, &str) -> bool,
) -> anyhow::Result<()> {
    let mut stream = dial_with_backoff(addr, net.connect_timeout)?;
    stream.set_nodelay(true).ok();
    let mut msg_buf = Vec::new();
    let mut frame_buf = Vec::new();
    Msg::Subscribe { interval_ms }.encode_into(&mut msg_buf)?;
    encode_frame_into(&msg_buf, &mut frame_buf);
    stream.write_all(&frame_buf)?;
    let mut reader = FrameReader::new();
    let mut payload = Vec::new();
    stream.set_read_timeout(Some(POLL))?;
    let mut chunk = [0u8; 16 * 1024];
    let mut last_rx = Instant::now();
    let mut last_hb = Instant::now();
    let mut hb_seq = 0u64;
    // Deltas may arrive slower than the heartbeat timeout: tolerate a
    // couple of missed intervals before declaring the server gone.
    let silence_cap = net
        .hb_timeout
        .max(Duration::from_millis(u64::from(interval_ms) * 2 + 1000));
    loop {
        if last_hb.elapsed() >= net.hb_interval {
            last_hb = Instant::now();
            hb_seq += 1;
            Msg::Heartbeat { seq: hb_seq }.encode_into(&mut msg_buf)?;
            frame_buf.clear();
            encode_frame_into(&msg_buf, &mut frame_buf);
            stream.write_all(&frame_buf)?;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Ok(()), // server closed
            Ok(n) => {
                last_rx = Instant::now();
                reader.feed(&chunk[..n]);
                while reader.next_frame(&mut payload)? {
                    match Msg::decode(&payload)? {
                        Msg::StatusDelta { seq, json } => {
                            if !on_delta(seq, &json) {
                                return Ok(());
                            }
                        }
                        Msg::Heartbeat { .. } => {}
                        Msg::Shutdown => return Ok(()), // run over
                        other => anyhow::bail!("expected StatusDelta, got {other:?}"),
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if last_rx.elapsed() > silence_cap {
                    anyhow::bail!("server silent past the subscription interval");
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// One snapshot-plane message routed to the refresh path: a legacy full
/// slice (one message = one complete response) or one chunk of a delta
/// stream (the chunk flagged `done` terminates the response).
enum SnapUpdate {
    Full {
        shard: usize,
        version: u64,
        theta: Vec<f32>,
    },
    Delta {
        shard: usize,
        version: u64,
        dtype: u8,
        done: bool,
        block_elems: u32,
        idx: Vec<u32>,
        lens: Vec<u32>,
        data: Vec<u8>,
    },
}

impl SnapUpdate {
    fn shard(&self) -> usize {
        match self {
            SnapUpdate::Full { shard, .. } | SnapUpdate::Delta { shard, .. } => *shard,
        }
    }

    /// Whether this message completes a snapshot response.
    fn terminal(&self) -> bool {
        match self {
            SnapUpdate::Full { .. } => true,
            SnapUpdate::Delta { done, .. } => *done,
        }
    }
}

/// One established client connection.
struct ClientConn {
    write: Arc<Mutex<TcpStream>>,
    acks_rx: Receiver<Reply>,
    snaps_rx: Receiver<SnapUpdate>,
    state: Arc<ConnState>,
    reader: Option<JoinHandle<()>>,
    hb: Option<JoinHandle<()>>,
    /// Dropping this wakes the heartbeat ticker out of its full-interval
    /// sleep so teardown never waits on it.
    hb_stop: Option<Sender<()>>,
}

impl Drop for ClientConn {
    fn drop(&mut self) {
        self.state.dead.store(true, Ordering::Relaxed);
        drop(self.hb_stop.take());
        // Unblock the reader promptly; ignore errors on an already-dead
        // socket.
        let _ = self.write.lock().unwrap().shutdown(std::net::Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
        if let Some(h) = self.hb.take() {
            let _ = h.join();
        }
    }
}

/// What the server told us at attach time.
#[derive(Clone, Copy, Debug)]
pub struct AttachInfo {
    pub worker: usize,
    /// Total worker slots of the run (data-sharding denominator).
    pub workers: usize,
    pub shards: usize,
    pub dim: usize,
    /// Whether this worker is in the delayed fraction (server-side draw,
    /// same derivation as the in-process trainer).
    pub delayed: bool,
}

/// The TCP implementation of [`Transport`]. See the module docs.
pub struct TcpTransport {
    addr: String,
    net: NetOptions,
    wire_desc: String,
    info: AttachInfo,
    layout: ShardLayout,
    conn: ClientConn,
    seq: u64,
    msg_buf: Vec<u8>,
    frame_buf: Vec<u8>,
    /// Submission-frame bytes written, cumulative across reconnects.
    submit_bytes: u64,
    /// Received bytes of connections already torn down.
    recv_bytes_prev: u64,
    /// Per-shard version of the last snapshot *fully applied* to the
    /// worker's buffer — what `refresh` claims in `SnapshotRequest` so the
    /// server can reply with only the blocks that moved. Only advanced on a
    /// complete application; a partial delta stream leaves it stale so the
    /// next request re-fetches every block that changed since.
    have_versions: Vec<u64>,
    /// Per-shard count of snapshot responses requested but not yet fully
    /// consumed. Responses arrive in request order (one writer, FIFO), so
    /// when this is > 1 the incoming stream belongs to an older, abandoned
    /// request (e.g. a refresh that timed out mid-stream) and must be
    /// skipped through its terminal chunk. Reset on reconnect: a fresh
    /// connection has no outstanding responses.
    snap_pending: Vec<u64>,
    /// Snapshot-response payload bytes consumed by `refresh` (full slices
    /// and delta chunks, message payload granularity). With the delta
    /// protocol this measures blocks actually shipped, not slice sizes —
    /// the worker reports it at run end via `refresh_wire_bytes`.
    refresh_bytes: u64,
}

/// Outcome of one attach attempt: an established connection, or the
/// server's typed terminal refusal (`Msg::Evict` — the requested identity
/// was reassigned; redialing under it can never succeed). Retryable
/// failures (dial errors, `Shutdown` refusals, handshake timeouts) stay
/// `Err`.
enum Attach {
    Ok(ClientConn, AttachInfo),
    Evicted,
}

impl TcpTransport {
    /// Dial `addr` (with backoff), attach as a new worker and learn the
    /// run's geometry from the server's `Welcome`. `wire_desc` is the
    /// worker's `WireFormat` in display syntax (telemetry/validation).
    pub fn connect(addr: &str, wire_desc: &str, net: NetOptions) -> anyhow::Result<TcpTransport> {
        let (conn, info) = match Self::establish(addr, &net, WORKER_UNASSIGNED, wire_desc)? {
            Attach::Ok(conn, info) => (conn, info),
            Attach::Evicted => anyhow::bail!(
                "evicted by the server: this worker's slot is gone (reassigned \
                 to a replacement, or the elastic run declared it dead)"
            ),
        };
        let layout = ShardLayout::new(info.dim, info.shards);
        anyhow::ensure!(
            layout.shards() == info.shards,
            "server advertised {} shards for dim {} (impossible layout)",
            info.shards,
            info.dim
        );
        Ok(TcpTransport {
            addr: addr.to_string(),
            net,
            wire_desc: wire_desc.to_string(),
            info,
            layout,
            conn,
            seq: 0,
            msg_buf: Vec::new(),
            frame_buf: Vec::new(),
            submit_bytes: 0,
            recv_bytes_prev: 0,
            have_versions: vec![0; info.shards],
            snap_pending: vec![0; info.shards],
            refresh_bytes: 0,
        })
    }

    /// Attach metadata from the server's `Welcome`.
    pub fn attach_info(&self) -> AttachInfo {
        self.info
    }

    /// Test hook: hard-close the underlying socket out from under the
    /// transport, simulating a network drop (the reconnect tests in this
    /// module and the reactor's use it).
    #[cfg(test)]
    pub(crate) fn kill_socket_for_test(&self) {
        let _ = self
            .conn
            .write
            .lock()
            .unwrap()
            .shutdown(std::net::Shutdown::Both);
    }

    fn establish(
        addr: &str,
        net: &NetOptions,
        worker: u32,
        wire_desc: &str,
    ) -> anyhow::Result<Attach> {
        let mut stream = dial_with_backoff(addr, net.connect_timeout)?;
        stream.set_nodelay(true).ok();
        let mut reader = FrameReader::new();
        let mut payload = Vec::new();
        let mut msg_buf = Vec::new();
        let mut frame_buf = Vec::new();
        // Hello → Welcome, inline (no threads yet).
        {
            let hello = Msg::Hello {
                worker,
                shards: 0,
                wire: wire_desc.to_string(),
            };
            hello.encode_into(&mut msg_buf)?;
            frame_buf.clear();
            encode_frame_into(&msg_buf, &mut frame_buf);
            stream.write_all(&frame_buf)?;
        }
        let deadline = Instant::now() + net.hb_timeout;
        // Read until the Welcome. Stray data-plane messages (acks or
        // snapshot slices queued for our slot before a reconnect) belong
        // to the round the old connection lost — skip them.
        let info = loop {
            let msg = read_msg_blocking(&mut stream, &mut reader, &mut payload, deadline)?;
            match msg {
                Msg::Welcome {
                    worker,
                    workers,
                    shards,
                    dim,
                    delayed,
                } => {
                    break AttachInfo {
                        worker: worker as usize,
                        workers: workers as usize,
                        shards: shards as usize,
                        dim: dim as usize,
                        delayed,
                    }
                }
                Msg::Shutdown => anyhow::bail!(
                    "server refused the attach (no free worker slot, or the run is over)"
                ),
                Msg::Evict { .. } => return Ok(Attach::Evicted),
                Msg::GradAck { .. }
                | Msg::SnapshotSlice { .. }
                | Msg::SnapshotDelta { .. }
                | Msg::Heartbeat { .. } => {}
                other => anyhow::bail!("expected Welcome, got {other:?}"),
            }
        };
        let state = ConnState::new();
        state.mark_rx();
        let (acks_tx, acks_rx) = mpsc::channel();
        let (snaps_tx, snaps_rx) = mpsc::channel();
        let read_stream = stream.try_clone()?;
        let write = Arc::new(Mutex::new(stream));
        let reader_handle = {
            let state = Arc::clone(&state);
            let hb_timeout = net.hb_timeout;
            std::thread::spawn(move || {
                client_read_loop(read_stream, reader, state, acks_tx, snaps_tx, hb_timeout)
            })
        };
        let (hb_stop_tx, hb_stop_rx) = mpsc::channel::<()>();
        let hb_handle = {
            let state = Arc::clone(&state);
            let write = Arc::clone(&write);
            let interval = net.hb_interval;
            std::thread::spawn(move || heartbeat_loop(write, state, interval, hb_stop_rx))
        };
        Ok(Attach::Ok(
            ClientConn {
                write,
                acks_rx,
                snaps_rx,
                state,
                reader: Some(reader_handle),
                hb: Some(hb_handle),
                hb_stop: Some(hb_stop_tx),
            },
            info,
        ))
    }

    fn dead(&self) -> bool {
        self.conn.state.dead.load(Ordering::Relaxed)
    }

    /// The connection is gone: redial and re-attach under our assigned id.
    /// `Ok(())` means a fresh connection is up (the caller still reports
    /// `Reconnected` so the worker loop resynchronizes).
    ///
    /// A refused Hello usually means the server has not yet reaped our
    /// previous connection's slot — after a half-open drop that takes the
    /// server up to its own heartbeat timeout to notice. So the retry
    /// budget is both a minimum attempt count (`reconnect_attempts`) *and*
    /// a minimum time window spanning that reap latency; giving up any
    /// earlier would turn every silent drop into a dead worker.
    fn reconnect(&mut self) -> Result<(), TransportError> {
        if self.conn.state.shutdown.load(Ordering::Relaxed) {
            return Err(TransportError::Closed("server sent Shutdown".into()));
        }
        let start = Instant::now();
        let min_window = self.net.hb_timeout + self.net.hb_interval * 2;
        let mut last = String::from("no attempt made");
        let mut attempt = 0u32;
        loop {
            match Self::establish(
                &self.addr,
                &self.net,
                self.info.worker as u32,
                &self.wire_desc,
            ) {
                Ok(Attach::Evicted) => {
                    // Terminal: the slot belongs to someone else now.
                    // Redialing under this identity can never succeed.
                    return Err(TransportError::Closed(
                        "evicted: the server reassigned this worker's slot".into(),
                    ));
                }
                Ok(Attach::Ok(conn, info)) => {
                    if info.worker != self.info.worker
                        || info.shards != self.info.shards
                        || info.dim != self.info.dim
                    {
                        return Err(TransportError::Closed(format!(
                            "server geometry changed across reconnect: {:?} vs {:?}",
                            info, self.info
                        )));
                    }
                    self.recv_bytes_prev +=
                        self.conn.state.bytes_received.load(Ordering::Relaxed);
                    self.conn = conn; // old conn Drop joins its threads
                    // The fresh connection has no outstanding snapshot
                    // responses; `have_versions` survives — the worker's
                    // buffer still holds whatever was last fully applied.
                    self.snap_pending.iter_mut().for_each(|p| *p = 0);
                    log_warn!(
                        "transport",
                        "worker {} reconnected to {} (attempt {})",
                        self.info.worker,
                        self.addr,
                        attempt + 1
                    );
                    return Ok(());
                }
                Err(e) => last = format!("{e:#}"),
            }
            attempt += 1;
            if attempt >= self.net.reconnect_attempts.max(1) && start.elapsed() >= min_window {
                break;
            }
            std::thread::sleep(Duration::from_millis(50 * u64::from(attempt.min(8))));
        }
        Err(TransportError::Closed(format!(
            "reconnect to {} failed after {attempt} attempts over {:.1}s: {last}",
            self.addr,
            start.elapsed().as_secs_f64()
        )))
    }

    /// Reconnect and translate into the caller-visible error.
    fn handle_loss(&mut self) -> TransportError {
        match self.reconnect() {
            Ok(()) => TransportError::Reconnected,
            Err(e) => e,
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // Best-effort clean goodbye: under elastic membership the server
        // removes this worker from the barrier denominator immediately
        // instead of waiting out the heartbeat timeout. A dead socket just
        // means the server finds out the slow way.
        if !self.dead() && !self.conn.state.shutdown.load(Ordering::Relaxed) {
            let leave = Msg::Leave {
                worker: self.info.worker as u32,
            };
            let _ = write_msg(
                &self.conn.write,
                &leave,
                &mut self.msg_buf,
                &mut self.frame_buf,
            );
        }
    }
}

impl Transport for TcpTransport {
    fn layout(&self) -> &ShardLayout {
        &self.layout
    }

    fn submit(&mut self, shard: usize, msg: ShardMsg) -> Result<(), TransportError> {
        if self.dead() {
            return Err(self.handle_loss());
        }
        let range = self.layout.range(shard);
        encode_submit_into(
            shard as u32,
            self.seq,
            msg.base_version,
            msg.loss,
            &msg.grad,
            range,
            &mut self.msg_buf,
        )
        .map_err(|e| TransportError::Closed(format!("unencodable gradient: {e}")))?;
        self.seq += 1;
        self.frame_buf.clear();
        encode_frame_into(&self.msg_buf, &mut self.frame_buf);
        let res = {
            let mut s = self.conn.write.lock().unwrap();
            s.write_all(&self.frame_buf)
        };
        match res {
            Ok(()) => {
                self.submit_bytes += self.frame_buf.len() as u64;
                Ok(())
            }
            Err(_) => {
                self.conn.state.dead.store(true, Ordering::Relaxed);
                Err(self.handle_loss())
            }
        }
    }

    fn recv_reply(&mut self, timeout: Duration) -> Result<Reply, TransportError> {
        if self.dead() {
            return Err(self.handle_loss());
        }
        match self.conn.acks_rx.recv_timeout(timeout) {
            Ok(r) => Ok(r),
            Err(RecvTimeoutError::Timeout) => {
                if self.dead() {
                    Err(self.handle_loss())
                } else {
                    Err(TransportError::Timeout)
                }
            }
            Err(RecvTimeoutError::Disconnected) => Err(self.handle_loss()),
        }
    }

    /// Fetch the latest published snapshot into `out`. `out` must still
    /// hold the result of this transport's previous successful refresh of
    /// `shard` (the worker's parameter slice does) — the request claims
    /// that version, and a delta reply only carries the blocks that moved
    /// since. `have_versions` advances *only* when a response is applied
    /// completely, so a refresh abandoned mid-stream (timeout, apply
    /// error) self-repairs: the next request re-claims the old version and
    /// the server re-sends every block that changed after it.
    fn refresh(&mut self, shard: usize, out: &mut [f32]) -> Result<u64, TransportError> {
        if self.dead() {
            return Err(self.handle_loss());
        }
        let req = Msg::SnapshotRequest {
            shard: shard as u32,
            version: self.have_versions[shard],
        };
        if write_msg(
            &self.conn.write,
            &req,
            &mut self.msg_buf,
            &mut self.frame_buf,
        )
        .is_err()
        {
            self.conn.state.dead.store(true, Ordering::Relaxed);
            return Err(self.handle_loss());
        }
        self.snap_pending[shard] += 1;
        // Version of the delta stream currently being applied to `out`.
        let mut applying: Option<u64> = None;
        let deadline = Instant::now() + self.net.hb_timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(TransportError::Timeout);
            }
            match self.conn.snaps_rx.recv_timeout(remaining.min(POLL.max(Duration::from_millis(50)))) {
                Ok(upd) => {
                    let s = upd.shard();
                    if s >= self.snap_pending.len() {
                        continue; // impossible shard id: drop
                    }
                    // Responses arrive in request order, so while more than
                    // one response is outstanding for a shard the incoming
                    // stream answers an older, abandoned request — skip it
                    // whole; its terminal chunk retires that response.
                    if s != shard || self.snap_pending[s] > 1 {
                        if upd.terminal() {
                            self.snap_pending[s] = self.snap_pending[s].saturating_sub(1);
                        }
                        continue;
                    }
                    match upd {
                        SnapUpdate::Full { version, theta, .. } => {
                            self.snap_pending[shard] -= 1;
                            self.refresh_bytes += snapshot_slice_bytes(theta.len()) as u64;
                            if theta.len() != out.len() {
                                return Err(TransportError::Closed(format!(
                                    "snapshot slice for shard {shard} has {} params, expected {}",
                                    theta.len(),
                                    out.len()
                                )));
                            }
                            out.copy_from_slice(&theta);
                            self.have_versions[shard] = version;
                            return Ok(version);
                        }
                        SnapUpdate::Delta {
                            version,
                            dtype,
                            done,
                            block_elems,
                            idx,
                            lens,
                            data,
                            ..
                        } => {
                            self.refresh_bytes +=
                                (SNAP_DELTA_HEADER_BYTES + 8 * idx.len() + data.len()) as u64;
                            // One response is built from one published
                            // snapshot; a version change mid-stream means
                            // the stream is not self-consistent.
                            if applying.map_or(false, |v| v != version) {
                                return Err(TransportError::Closed(format!(
                                    "snapshot delta stream for shard {shard} changed \
                                     version mid-flight ({} -> {version})",
                                    applying.unwrap()
                                )));
                            }
                            applying = Some(version);
                            if let Err(e) = apply_snapshot_delta(
                                dtype,
                                block_elems,
                                &idx,
                                &lens,
                                &data,
                                out,
                            ) {
                                return Err(TransportError::Closed(format!(
                                    "snapshot delta for shard {shard}: {e}"
                                )));
                            }
                            if done {
                                self.snap_pending[shard] -= 1;
                                self.have_versions[shard] = version;
                                return Ok(version);
                            }
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    if self.dead() {
                        return Err(self.handle_loss());
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return Err(self.handle_loss()),
            }
        }
    }

    fn wire_counters(&self) -> Option<(u64, u64)> {
        let received =
            self.recv_bytes_prev + self.conn.state.bytes_received.load(Ordering::Relaxed);
        Some((self.submit_bytes, received))
    }

    fn refresh_wire_bytes(&self) -> Option<u64> {
        Some(self.refresh_bytes)
    }
}

/// Client reader thread: decode frames, route replies and snapshots, track
/// liveness. Exits (marking the connection dead) on socket close, I/O
/// error, a corrupt stream, `Shutdown`, or heartbeat silence.
fn client_read_loop(
    mut stream: TcpStream,
    mut reader: FrameReader,
    state: Arc<ConnState>,
    acks_tx: Sender<Reply>,
    snaps_tx: Sender<SnapUpdate>,
    hb_timeout: Duration,
) {
    let _ = stream.set_read_timeout(Some(POLL));
    let mut chunk = [0u8; 64 * 1024];
    let mut payload = Vec::new();
    'outer: loop {
        if state.dead.load(Ordering::Relaxed) {
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                state.mark_rx();
                state.bytes_received.fetch_add(n as u64, Ordering::Relaxed);
                reader.feed(&chunk[..n]);
                loop {
                    match reader.next_frame(&mut payload) {
                        Ok(true) => match Msg::decode(&payload) {
                            Ok(Msg::GradAck {
                                shard,
                                version,
                                changed,
                            }) => {
                                let reply = if changed {
                                    Reply::Updated {
                                        shard: shard as usize,
                                        version,
                                    }
                                } else {
                                    Reply::Unchanged {
                                        shard: shard as usize,
                                    }
                                };
                                if acks_tx.send(reply).is_err() {
                                    break 'outer;
                                }
                            }
                            Ok(Msg::SnapshotSlice {
                                shard,
                                version,
                                theta,
                            }) => {
                                let upd = SnapUpdate::Full {
                                    shard: shard as usize,
                                    version,
                                    theta,
                                };
                                if snaps_tx.send(upd).is_err() {
                                    break 'outer;
                                }
                            }
                            Ok(Msg::SnapshotDelta {
                                shard,
                                version,
                                dtype,
                                done,
                                block_elems,
                                idx,
                                lens,
                                data,
                            }) => {
                                let upd = SnapUpdate::Delta {
                                    shard: shard as usize,
                                    version,
                                    dtype,
                                    done,
                                    block_elems,
                                    idx,
                                    lens,
                                    data,
                                };
                                if snaps_tx.send(upd).is_err() {
                                    break 'outer;
                                }
                            }
                            Ok(Msg::Heartbeat { .. }) => {}
                            Ok(Msg::Shutdown) => {
                                state.shutdown.store(true, Ordering::Relaxed);
                                break 'outer;
                            }
                            Ok(Msg::Evict { .. }) => {
                                // Terminal like Shutdown: reconnecting
                                // under the evicted identity is pointless.
                                state.shutdown.store(true, Ordering::Relaxed);
                                break 'outer;
                            }
                            Ok(_) => {} // unexpected control message: ignore
                            Err(e) => {
                                log_warn!("transport", "client dropping corrupt stream: {e}");
                                break 'outer;
                            }
                        },
                        Ok(false) => break,
                        Err(e) => {
                            log_warn!("transport", "client dropping corrupt stream: {e}");
                            break 'outer;
                        }
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.silent_for() > hb_timeout {
                    log_warn!("transport", "peer silent past the heartbeat timeout (half-open)");
                    break;
                }
            }
            Err(_) => break,
        }
    }
    state.dead.store(true, Ordering::Relaxed);
}

/// Heartbeat ticker: one `Heartbeat` frame per interval until the
/// connection dies. Blocks a full interval on the stop channel instead of
/// polling in 25 ms slices — an idle joined worker wakes 2×/sec at the
/// default interval, not 40×/sec — while teardown (which drops the
/// sender) still interrupts the sleep immediately.
fn heartbeat_loop(
    write: Arc<Mutex<TcpStream>>,
    state: Arc<ConnState>,
    interval: Duration,
    stop_rx: Receiver<()>,
) {
    let mut msg_buf = Vec::new();
    let mut frame_buf = Vec::new();
    let mut seq = 0u64;
    loop {
        match stop_rx.recv_timeout(interval) {
            Ok(()) | Err(RecvTimeoutError::Disconnected) => break, // teardown
            Err(RecvTimeoutError::Timeout) => {}
        }
        if state.dead.load(Ordering::Relaxed) {
            break;
        }
        seq += 1;
        if write_msg(&write, &Msg::Heartbeat { seq }, &mut msg_buf, &mut frame_buf).is_err() {
            state.dead.store(true, Ordering::Relaxed);
            break;
        }
    }
}

// ---------------------------------------------------------------------------
// server
// ---------------------------------------------------------------------------

/// One worker slot on the serving side.
struct Slot {
    attached: bool,
    /// What the current occupant's `Hello` requested ([`WORKER_UNASSIGNED`]
    /// for a fresh/replacement worker, the slot id for a reconnect).
    /// Meaningful only while `attached`.
    taken_as: u32,
    /// Whether the current occupant attached *after* the slot had been
    /// vacated at least once. Together with `taken_as` this classifies a
    /// busy-slot named re-attach under elastic membership: a fresh
    /// occupant on a previously vacated slot is a **replacement** (the
    /// requester is evicted, terminally), anything else is plausibly the
    /// requester's own not-yet-reaped connection (retryable refusal).
    taken_after_vacancy: bool,
    /// Times this slot has been vacated (connection teardowns).
    vacancies: u64,
    /// Present while no connection owns the slot; the reply pump takes it
    /// and hands it back on disconnect (reconnect support).
    reply_rx: Option<Receiver<Reply>>,
}

/// Shared state of the serving frontend.
struct Shared {
    layout: ShardLayout,
    grad_txs: Vec<Sender<ShardEvent>>,
    cells: Vec<Arc<SnapshotCell>>,
    slots: Mutex<Vec<Slot>>,
    delayed: Vec<bool>,
    stop: Arc<AtomicBool>,
    net: NetOptions,
    /// Elastic membership: report attaches/departures to the shard servers
    /// as `ShardEvent::Join`/`Leave` and evict (instead of refuse-and-retry)
    /// a worker whose slot is taken.
    elastic: bool,
    /// Per-shard live counters published by `run_shard` (the ops plane);
    /// `None` when serving without a status board (unit tests).
    status: Option<Arc<StatusBoard>>,
    /// Flight recorder for the gradient lifecycle; `None` keeps the hot
    /// path free of clock reads (`--trace` off).
    trace: Option<Arc<TraceRing>>,
    /// When serving began (uptime / bytes-per-second basis).
    started: Instant,
    /// Submission frames received, frame-granularity bytes.
    grad_frame_bytes: AtomicU64,
    /// Distinct submissions seen (shard-0 submit frames).
    submissions: AtomicU64,
    active_conns: AtomicUsize,
    ever_joined: AtomicUsize,
    conn_handles: Mutex<Vec<JoinHandle<()>>>,
}

/// Gradient-plane counters of a [`ThreadedFrontend`].
#[derive(Clone, Copy, Debug, Default)]
pub struct FrontendStats {
    /// Bytes of submission frames received (headers + payload + CRC).
    pub grad_frame_bytes: u64,
    /// Submissions received (one per worker iteration, not per shard).
    pub submissions: u64,
}

/// The server-side TCP acceptor + per-connection bridging threads.
pub struct ThreadedFrontend {
    shared: Arc<Shared>,
    acceptor: Option<JoinHandle<()>>,
}

impl ThreadedFrontend {
    /// Start accepting workers. `reply_rxs[i]` is worker slot `i`'s reply
    /// channel (its senders already cloned into the shard threads);
    /// `delayed[i]` the slot's heterogeneity flag. The frontend owns
    /// clones of the gradient senders; [`ThreadedFrontend::shutdown`] drops
    /// them so the shard servers see disconnection exactly as when
    /// in-process workers finish.
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        listener: TcpListener,
        layout: ShardLayout,
        grad_txs: Vec<Sender<ShardEvent>>,
        cells: Vec<Arc<SnapshotCell>>,
        reply_rxs: Vec<Receiver<Reply>>,
        delayed: Vec<bool>,
        stop: Arc<AtomicBool>,
        net: NetOptions,
        elastic: bool,
        status: Option<Arc<StatusBoard>>,
        trace: Option<Arc<TraceRing>>,
    ) -> std::io::Result<ThreadedFrontend> {
        listener.set_nonblocking(true)?;
        let slots = reply_rxs
            .into_iter()
            .map(|rx| Slot {
                attached: false,
                taken_as: WORKER_UNASSIGNED,
                taken_after_vacancy: false,
                vacancies: 0,
                reply_rx: Some(rx),
            })
            .collect();
        let shared = Arc::new(Shared {
            layout,
            grad_txs,
            cells,
            slots: Mutex::new(slots),
            delayed,
            stop,
            net,
            elastic,
            status,
            trace,
            started: Instant::now(),
            grad_frame_bytes: AtomicU64::new(0),
            submissions: AtomicU64::new(0),
            active_conns: AtomicUsize::new(0),
            ever_joined: AtomicUsize::new(0),
            conn_handles: Mutex::new(Vec::new()),
        });
        let acceptor = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(listener, shared))
        };
        Ok(ThreadedFrontend {
            shared,
            acceptor: Some(acceptor),
        })
    }

    /// Workers currently connected.
    pub fn active_conns(&self) -> usize {
        self.shared.active_conns.load(Ordering::Relaxed)
    }

    /// Workers that have ever completed an attach.
    pub fn ever_joined(&self) -> usize {
        self.shared.ever_joined.load(Ordering::Relaxed)
    }

    /// Gradient-plane byte counters.
    pub fn stats(&self) -> FrontendStats {
        FrontendStats {
            grad_frame_bytes: self.shared.grad_frame_bytes.load(Ordering::Relaxed),
            submissions: self.shared.submissions.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting, disconnect every worker (they receive `Shutdown`),
    /// join all connection threads and release the gradient senders so
    /// the shard servers can drain and exit.
    pub fn shutdown(mut self) -> FrontendStats {
        self.shared.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        loop {
            let handle = self.shared.conn_handles.lock().unwrap().pop();
            match handle {
                Some(h) => {
                    let _ = h.join();
                }
                None => break,
            }
        }
        self.stats()
        // `self.shared` drops here; with every handler joined this is the
        // last owner of the gradient senders.
    }
}

/// Join (and drop) every finished connection thread so a long-lived
/// server with reconnect churn or refused attaches does not accumulate
/// handles without bound; live connections stay registered for
/// `shutdown` to join.
fn reap_finished(shared: &Shared) {
    let mut handles = shared.conn_handles.lock().unwrap();
    let mut i = 0;
    while i < handles.len() {
        if handles[i].is_finished() {
            let h = handles.swap_remove(i);
            let _ = h.join();
        } else {
            i += 1;
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            break;
        }
        reap_finished(&shared);
        match listener.accept() {
            Ok((stream, peer)) => {
                let shared2 = Arc::clone(&shared);
                let handle = std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, &shared2) {
                        log_warn!("transport", "connection from {peer} ended: {e:#}");
                    }
                });
                shared.conn_handles.lock().unwrap().push(handle);
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                std::thread::sleep(POLL);
            }
            Err(e) => {
                log_warn!("transport", "accept failed: {e}");
                std::thread::sleep(POLL);
            }
        }
    }
}

/// The status document (DESIGN.md §2.9), assembled from atomics.
fn status_doc(shared: &Shared) -> String {
    super::render_status(
        "threaded",
        &shared.layout,
        shared.delayed.len(),
        shared.active_conns.load(Ordering::Relaxed),
        shared.ever_joined.load(Ordering::Relaxed),
        shared.grad_frame_bytes.load(Ordering::Relaxed),
        shared.submissions.load(Ordering::Relaxed),
        shared.started.elapsed(),
        shared.status.as_deref(),
        shared.trace.as_deref(),
    )
}

/// Push loop for a handshake-phase status subscriber: one `StatusDelta`
/// immediately, then one per interval, until the follower disconnects,
/// goes silent past the heartbeat timeout, or the run stops. The follower
/// keeps itself alive with `Heartbeat` frames; a fresh `Subscribe`
/// retimes the cadence.
fn follow_loop(
    mut stream: TcpStream,
    shared: &Shared,
    interval_ms: u32,
    mut reader: FrameReader,
    mut payload: Vec<u8>,
) -> anyhow::Result<()> {
    let mut interval = Duration::from_millis(u64::from(interval_ms.max(10)));
    let mut msg_buf = Vec::new();
    let mut frame_buf = Vec::new();
    let mut push = |seq: u64, stream: &mut TcpStream, msg_buf: &mut Vec<u8>, frame_buf: &mut Vec<u8>| {
        let json = status_doc(shared);
        Msg::StatusDelta { seq, json }
            .encode_into(msg_buf)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
        frame_buf.clear();
        encode_frame_into(msg_buf, frame_buf);
        stream.write_all(frame_buf)
    };
    let mut seq = 0u64;
    push(seq, &mut stream, &mut msg_buf, &mut frame_buf)?;
    seq += 1;
    let mut next = Instant::now() + interval;
    let state = ConnState::new();
    state.mark_rx();
    stream.set_read_timeout(Some(POLL))?;
    let mut chunk = [0u8; 16 * 1024];
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        if Instant::now() >= next {
            push(seq, &mut stream, &mut msg_buf, &mut frame_buf)?;
            seq += 1;
            next = Instant::now() + interval;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Ok(()), // follower left
            Ok(n) => {
                state.mark_rx();
                reader.feed(&chunk[..n]);
                while reader.next_frame(&mut payload)? {
                    match Msg::decode(&payload)? {
                        Msg::Heartbeat { .. } => {} // follower keepalive
                        Msg::Subscribe { interval_ms } => {
                            interval = Duration::from_millis(u64::from(interval_ms.max(10)));
                            next = Instant::now();
                        }
                        Msg::Shutdown => return Ok(()), // clean goodbye
                        other => anyhow::bail!("follower sent unexpected {other:?}"),
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.silent_for() > shared.net.hb_timeout {
                    anyhow::bail!("follower silent past the heartbeat timeout");
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Serve one worker connection end to end. Returns when the worker
/// disconnects, the stream corrupts, liveness lapses, or the run stops.
fn handle_conn(mut stream: TcpStream, shared: &Shared) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = FrameReader::new();
    let mut payload = Vec::new();
    // --- attach handshake ---
    let deadline = Instant::now() + shared.net.hb_timeout;
    let hello = read_msg_blocking(&mut stream, &mut reader, &mut payload, deadline)?;
    // A status probe never takes a worker slot: answer inline on the
    // handshake path and let the probe close the connection.
    if matches!(hello, Msg::StatusRequest) {
        let mut msg_buf = Vec::new();
        let mut frame_buf = Vec::new();
        let mut s = Mutex::new(stream);
        let json = status_doc(shared);
        let _ = write_msg(&s, &Msg::Status { json }, &mut msg_buf, &mut frame_buf);
        let _ = s.get_mut().unwrap().flush();
        return Ok(());
    }
    // A subscription likewise stays off the worker slots: this handler
    // thread becomes the follower's push loop until it disconnects, the
    // run stops, or it goes silent past the heartbeat timeout.
    if let Msg::Subscribe { interval_ms } = hello {
        return follow_loop(stream, shared, interval_ms, reader, payload);
    }
    let (requested, wire) = match hello {
        Msg::Hello { worker, wire, .. } => (worker, wire),
        other => anyhow::bail!("expected Hello, got {other:?}"),
    };
    let mut msg_buf = Vec::new();
    let mut frame_buf = Vec::new();
    // Slot assignment. On refusal, `evicted` distinguishes the terminal
    // case (under elastic membership, a *replacement* worker owns the
    // requested slot — the requester lost its identity and must not keep
    // redialing) from the retryable one (the requester's own dead
    // connection has not been reaped yet, or the run is simply full). A
    // replacement is recognizable as a fresh (unassigned) attach on a slot
    // that had been vacated; a first-ever connection that goes half-open
    // has never vacated its slot, so its owner's redial stays retryable.
    // (Residual window: a replacement's *own* first blip inside the reap
    // latency is also classified as eviction — a conservative
    // over-eviction an elastic run absorbs by admitting a fresh joiner.)
    let (assigned, evicted) = {
        let mut slots = shared.slots.lock().unwrap();
        let mut evicted = false;
        let id = if requested == WORKER_UNASSIGNED {
            slots
                .iter()
                .position(|s| !s.attached && s.reply_rx.is_some())
        } else {
            let id = requested as usize;
            match slots.get(id) {
                Some(s) if !s.attached && s.reply_rx.is_some() => Some(id),
                Some(s) if s.attached => {
                    evicted = shared.elastic
                        && s.taken_as == WORKER_UNASSIGNED
                        && s.taken_after_vacancy;
                    None
                }
                _ => None,
            }
        };
        if let Some(id) = id {
            slots[id].attached = true;
            slots[id].taken_as = requested;
            slots[id].taken_after_vacancy = slots[id].vacancies > 0;
        }
        (id, evicted)
    };
    let Some(id) = assigned else {
        let refusal = if evicted {
            Msg::Evict { worker: requested }
        } else {
            Msg::Shutdown
        };
        let mut s = Mutex::new(stream);
        let _ = write_msg(&s, &refusal, &mut msg_buf, &mut frame_buf);
        let _ = s.get_mut().unwrap().flush();
        return Ok(());
    };
    log_warn!(
        "transport",
        "worker {id} attached (wire={wire}, requested={})",
        if requested == WORKER_UNASSIGNED {
            "new".to_string()
        } else {
            requested.to_string()
        }
    );
    shared.active_conns.fetch_add(1, Ordering::Relaxed);
    shared.ever_joined.fetch_add(1, Ordering::Relaxed);
    let conn_dead = Arc::new(AtomicBool::new(false));

    // --- writer thread: the only socket writer ---
    let (out_tx, out_rx) = mpsc::channel::<Msg>();
    let writer = {
        let stream = stream.try_clone()?;
        let conn_dead = Arc::clone(&conn_dead);
        let stop = Arc::clone(&shared.stop);
        let hb_interval = shared.net.hb_interval;
        std::thread::spawn(move || server_write_loop(stream, out_rx, conn_dead, stop, hb_interval))
    };
    // Welcome goes out before the reply pump starts: a re-attached slot's
    // channel can hold acks from the previous connection, and those must
    // never overtake the handshake.
    let _ = out_tx.send(Msg::Welcome {
        worker: id as u32,
        workers: shared.delayed.len() as u32,
        shards: shared.layout.shards() as u32,
        dim: shared.layout.dim() as u64,
        delayed: shared.delayed[id],
    });
    // Elastic membership: announce the attach to every shard before any of
    // this connection's gradients can reach them (same channel ⇒ FIFO).
    // Joins are idempotent on the shard side, so founding members and
    // reconnects are safe to announce unconditionally.
    if shared.elastic {
        for tx in &shared.grad_txs {
            let _ = tx.send(ShardEvent::Join { worker: id });
        }
    }
    // --- reply pump: shard replies → GradAck frames; owns the slot's rx ---
    let reply_rx = shared.slots.lock().unwrap()[id]
        .reply_rx
        .take()
        .expect("attached slot lost its reply channel");
    let pump = {
        let out_tx = out_tx.clone();
        let conn_dead = Arc::clone(&conn_dead);
        std::thread::spawn(move || -> Receiver<Reply> {
            loop {
                if conn_dead.load(Ordering::Relaxed) {
                    break;
                }
                match reply_rx.recv_timeout(POLL) {
                    Ok(reply) => {
                        let msg = match reply {
                            Reply::Updated { shard, version } => Msg::GradAck {
                                shard: shard as u32,
                                version,
                                changed: true,
                            },
                            Reply::Unchanged { shard } => Msg::GradAck {
                                shard: shard as u32,
                                version: 0,
                                changed: false,
                            },
                        };
                        if out_tx.send(msg).is_err() {
                            break;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            reply_rx
        })
    };

    // --- reader loop (this thread) ---
    let state = ConnState::new();
    state.mark_rx();
    let _ = stream.set_read_timeout(Some(POLL));
    let mut chunk = [0u8; 64 * 1024];
    let result = server_read_loop(
        &mut stream,
        &mut reader,
        &mut payload,
        &mut chunk,
        shared,
        id,
        &state,
        &out_tx,
    );

    // --- teardown ---
    // A for-cause exit of an attached worker (corrupt stream, liveness
    // lapse) is an eviction from the frontend's perspective.
    if result.is_err() {
        if let Some(tr) = &shared.trace {
            tr.instant(Stage::Evict, id as u32, 0, tr.real_now(), 0, 0);
        }
    }
    conn_dead.store(true, Ordering::Relaxed);
    drop(out_tx); // writer drains, sends Shutdown if stopping, exits
    let _ = writer.join();
    let rx = pump.join().expect("reply pump panicked");
    // Elastic membership: the worker is gone — clean goodbye, socket close,
    // or heartbeat-timeout eviction all look the same from here. Announce
    // the departure (after the reader exited, so it sequences after every
    // gradient this connection delivered) *before* freeing the slot, so a
    // replacement's Join can never overtake this Leave. Suppressed once
    // the run is stopping: end-of-run disconnects are not churn.
    if shared.elastic && !shared.stop.load(Ordering::Relaxed) {
        for tx in &shared.grad_txs {
            let _ = tx.send(ShardEvent::Leave { worker: id });
        }
    }
    {
        let mut slots = shared.slots.lock().unwrap();
        slots[id].reply_rx = Some(rx);
        slots[id].attached = false;
        slots[id].vacancies += 1;
    }
    shared.active_conns.fetch_sub(1, Ordering::Relaxed);
    result
}

/// The per-connection frame-decode loop (runs on the handler thread).
#[allow(clippy::too_many_arguments)]
fn server_read_loop(
    stream: &mut TcpStream,
    reader: &mut FrameReader,
    payload: &mut Vec<u8>,
    chunk: &mut [u8],
    shared: &Shared,
    id: usize,
    state: &ConnState,
    out_tx: &Sender<Msg>,
) -> anyhow::Result<()> {
    // Active status subscription of this (attached) worker, if any:
    // (interval, next delta seq, next push due). Serviced on every loop
    // iteration, so cadence granularity is the poll slice.
    let mut sub: Option<(Duration, u64, Instant)> = None;
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        if let Some((interval, seq, next)) = sub.as_mut() {
            if Instant::now() >= *next {
                let json = status_doc(shared);
                if out_tx.send(Msg::StatusDelta { seq: *seq, json }).is_err() {
                    return Ok(());
                }
                *seq += 1;
                *next = Instant::now() + *interval;
            }
        }
        match stream.read(chunk) {
            Ok(0) => return Ok(()), // worker left
            Ok(n) => {
                state.mark_rx();
                reader.feed(&chunk[..n]);
                while reader.next_frame(payload)? {
                    let frame_bytes = (payload.len() + FRAME_OVERHEAD) as u64;
                    match Msg::decode(payload)? {
                        Msg::SubmitGrad {
                            shard,
                            seq: _,
                            base_version,
                            loss,
                            grad,
                        } => {
                            let shard = shard as usize;
                            anyhow::ensure!(
                                shard < shared.layout.shards(),
                                "submit to shard {shard} of {}",
                                shared.layout.shards()
                            );
                            // Reject payloads sized for a different shard
                            // geometry *here*, before they reach a shard
                            // thread: `ShardGrad::view`'s size checks are
                            // debug-only, and a panicking shard thread
                            // would take the whole server down. Decode
                            // already guarantees sparse indices < the
                            // declared dim, so dim == shard length makes
                            // every scatter-add in bounds.
                            let expect = shared.layout.range(shard).len();
                            let local_len = match &grad {
                                ShardGrad::DenseLocal(g) => g.len(),
                                ShardGrad::QuantLocal(q) => q.data.len(),
                                ShardGrad::Sparse(s) => s.dim,
                                ShardGrad::SparseQuant(s) => s.dim,
                                // Full-dimension variants never come off
                                // the wire; their length cannot match a
                                // slice either, so this rejects them too.
                                ShardGrad::Dense(g) => g.len(),
                                ShardGrad::Quant(q) => q.data.len(),
                            };
                            anyhow::ensure!(
                                local_len == expect,
                                "worker {id} sent a shard-{shard} payload sized {local_len}, \
                                 expected {expect} (geometry mismatch)"
                            );
                            shared
                                .grad_frame_bytes
                                .fetch_add(frame_bytes, Ordering::Relaxed);
                            if shard == 0 {
                                shared.submissions.fetch_add(1, Ordering::Relaxed);
                            }
                            // Stamp the shard-queue entry time so
                            // `run_shard` can close the Queue span; 0
                            // (untraced) suppresses it.
                            let enq_ns =
                                shared.trace.as_ref().map_or(0, |tr| tr.real_now());
                            if shared.grad_txs[shard]
                                .send(ShardEvent::Grad(ShardMsg {
                                    worker: id,
                                    base_version,
                                    loss,
                                    grad,
                                    enq_ns,
                                }))
                                .is_err()
                            {
                                return Ok(()); // shards gone: run is over
                            }
                        }
                        Msg::SnapshotRequest { shard, version } => {
                            let shard = shard as usize;
                            anyhow::ensure!(
                                shard < shared.layout.shards(),
                                "snapshot request for shard {shard} of {}",
                                shared.layout.shards()
                            );
                            let snap = shared.cells[shard].load();
                            for m in snapshot_response_msgs(
                                shard as u32,
                                &snap,
                                version,
                                shared.net.snap_full_max,
                            ) {
                                if out_tx.send(m).is_err() {
                                    return Ok(());
                                }
                            }
                        }
                        Msg::Heartbeat { .. } => {}
                        Msg::Shutdown => return Ok(()), // clean client exit
                        // Clean departure: the teardown path announces the
                        // Leave to the shard servers without waiting for
                        // the socket to die or the heartbeat to lapse.
                        Msg::Leave { .. } => return Ok(()),
                        Msg::Hello { .. } => {}         // duplicate hello: ignore
                        Msg::StatusRequest => {
                            // Read-only ops probe from an attached worker;
                            // assembled from atomics, never the gradient
                            // plane.
                            let json = status_doc(shared);
                            if out_tx.send(Msg::Status { json }).is_err() {
                                return Ok(());
                            }
                        }
                        Msg::Subscribe { interval_ms } => {
                            // Attached workers may subscribe too; deltas
                            // interleave with acks on the writer channel.
                            let interval =
                                Duration::from_millis(u64::from(interval_ms.max(10)));
                            let seq = sub.as_ref().map_or(0, |&(_, s, _)| s);
                            let json = status_doc(shared);
                            if out_tx.send(Msg::StatusDelta { seq, json }).is_err() {
                                return Ok(());
                            }
                            sub = Some((interval, seq + 1, Instant::now() + interval));
                        }
                        other => {
                            log_warn!("transport", "worker {id} sent unexpected {other:?}");
                        }
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.silent_for() > shared.net.hb_timeout {
                    anyhow::bail!("worker {id} silent past the heartbeat timeout (half-open)");
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// The per-connection writer: encodes queued messages, emits heartbeats
/// when idle, and sends a final `Shutdown` when the run stops. Waits in
/// short slices (like the client's heartbeat ticker) so a dead
/// connection's teardown — and therefore its slot reap and elastic
/// `Leave` — is bounded by the poll granularity, not the heartbeat
/// interval.
fn server_write_loop(
    stream: TcpStream,
    out_rx: Receiver<Msg>,
    conn_dead: Arc<AtomicBool>,
    stop: Arc<AtomicBool>,
    hb_interval: Duration,
) {
    let stream = Mutex::new(stream);
    let mut msg_buf = Vec::new();
    let mut frame_buf = Vec::new();
    let mut hb_seq = 0u64;
    let mut shutdown_sent = false;
    let slice = POLL.min(hb_interval);
    let mut idle = Duration::ZERO;
    loop {
        if conn_dead.load(Ordering::Relaxed) {
            break;
        }
        if stop.load(Ordering::Relaxed) && !shutdown_sent {
            shutdown_sent = true;
            if write_msg(&stream, &Msg::Shutdown, &mut msg_buf, &mut frame_buf).is_err() {
                break;
            }
        }
        match out_rx.recv_timeout(slice) {
            Ok(msg) => {
                idle = Duration::ZERO;
                if write_msg(&stream, &msg, &mut msg_buf, &mut frame_buf).is_err() {
                    break;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                idle += slice;
                if idle >= hb_interval {
                    idle = Duration::ZERO;
                    hb_seq += 1;
                    if write_msg(
                        &stream,
                        &Msg::Heartbeat { seq: hb_seq },
                        &mut msg_buf,
                        &mut frame_buf,
                    )
                    .is_err()
                    {
                        break;
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Flush the stop signal if it raced the channel close.
                if stop.load(Ordering::Relaxed) && !shutdown_sent {
                    let _ = write_msg(&stream, &Msg::Shutdown, &mut msg_buf, &mut frame_buf);
                }
                break;
            }
        }
    }
    conn_dead.store(true, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_net() -> NetOptions {
        NetOptions {
            hb_interval: Duration::from_millis(50),
            hb_timeout: Duration::from_millis(400),
            connect_timeout: Duration::from_secs(3),
            reconnect_attempts: 1,
            ..NetOptions::default()
        }
    }

    /// Minimal in-test server: one shard, echoes every submit with an
    /// Updated ack, answers snapshots from a cell.
    fn spawn_frontend(
        workers: usize,
    ) -> (
        ThreadedFrontend,
        String,
        Vec<Receiver<ShardEvent>>,
        Vec<Sender<Reply>>,
        Arc<AtomicBool>,
    ) {
        spawn_frontend_opts(workers, false)
    }

    fn spawn_frontend_opts(
        workers: usize,
        elastic: bool,
    ) -> (
        ThreadedFrontend,
        String,
        Vec<Receiver<ShardEvent>>,
        Vec<Sender<Reply>>,
        Arc<AtomicBool>,
    ) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = format!("{}", listener.local_addr().unwrap());
        let layout = ShardLayout::new(4, 2);
        let mut grad_txs = Vec::new();
        let mut grad_rxs = Vec::new();
        for _ in 0..2 {
            let (tx, rx) = mpsc::channel();
            grad_txs.push(tx);
            grad_rxs.push(rx);
        }
        let mut reply_txs = Vec::new();
        let mut reply_rxs = Vec::new();
        for _ in 0..workers {
            let (tx, rx) = mpsc::channel();
            reply_txs.push(tx);
            reply_rxs.push(rx);
        }
        let cells = vec![
            Arc::new(SnapshotCell::new(vec![1.0, 2.0])),
            Arc::new(SnapshotCell::new(vec![3.0, 4.0])),
        ];
        let stop = Arc::new(AtomicBool::new(false));
        let frontend = ThreadedFrontend::start(
            listener,
            layout,
            grad_txs,
            cells,
            reply_rxs,
            vec![false; workers],
            Arc::clone(&stop),
            quick_net(),
            elastic,
            Some(Arc::new(StatusBoard::new(2))),
            None,
        )
        .unwrap();
        (frontend, addr, grad_rxs, reply_txs, stop)
    }

    /// Next gradient event from a shard channel (panics on control events).
    fn recv_grad(rx: &Receiver<ShardEvent>, timeout: Duration) -> ShardMsg {
        match rx.recv_timeout(timeout).expect("shard event") {
            ShardEvent::Grad(m) => m,
            other => panic!("expected a gradient, got a membership event: {:?}", kind(&other)),
        }
    }

    /// Next *membership* event from a shard channel, skipping gradients.
    fn recv_membership(rx: &Receiver<ShardEvent>, timeout: Duration) -> (bool, usize) {
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(remaining).expect("membership event") {
                ShardEvent::Join { worker } => return (true, worker),
                ShardEvent::Leave { worker } => return (false, worker),
                ShardEvent::Grad(_) => {}
            }
        }
    }

    fn kind(ev: &ShardEvent) -> &'static str {
        match ev {
            ShardEvent::Grad(_) => "grad",
            ShardEvent::Join { .. } => "join",
            ShardEvent::Leave { .. } => "leave",
        }
    }

    #[test]
    fn attach_submit_ack_refresh_roundtrip() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, grad_rxs, reply_txs, _stop) = spawn_frontend(2);
        let mut t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        let info = t.attach_info();
        assert_eq!(info.worker, 0);
        assert_eq!(info.workers, 2);
        assert_eq!(info.shards, 2);
        assert_eq!(info.dim, 4);
        assert_eq!(t.layout().shards(), 2);

        // refresh pulls the cell contents over the wire
        let mut buf = [0.0f32; 2];
        let v = t.refresh(1, &mut buf).unwrap();
        assert_eq!(v, 0);
        assert_eq!(buf, [3.0, 4.0]);

        // submit lands on the right shard channel as a shard-local payload
        t.submit(
            1,
            ShardMsg {
                worker: 0,
                base_version: 3,
                loss: 0.5,
                grad: ShardGrad::Dense(Arc::new(vec![1.0, 2.0, 3.0, 4.0])),
                enq_ns: 0,
            },
        )
        .unwrap();
        let msg = recv_grad(&grad_rxs[1], Duration::from_secs(2));
        assert_eq!(msg.worker, 0);
        assert_eq!(msg.base_version, 3);
        // shard 1's slice of the dense payload (range 2..4), shard-local
        let mut got = vec![0.0f32; 2];
        msg.grad.view(2..4).add_to(&mut got);
        assert_eq!(got, vec![3.0, 4.0]);
        assert_eq!(msg.grad.wire_bytes(2), 8);

        // an ack comes back as a Reply
        reply_txs[0]
            .send(Reply::Updated { shard: 1, version: 9 })
            .unwrap();
        let r = t.recv_reply(Duration::from_secs(2)).unwrap();
        assert_eq!(r, Reply::Updated { shard: 1, version: 9 });
        // frame-granularity counters are reported
        let (sent, _received) = t.wire_counters().unwrap();
        let expected = (crate::transport::frame::FRAME_OVERHEAD
            + crate::transport::msg::SUBMIT_HEADER_BYTES
            + crate::transport::msg::GRAD_DENSE_HEADER_BYTES
            + 8) as u64;
        assert_eq!(sent, expected);

        drop(t);
        frontend.shutdown();
    }

    #[test]
    fn oversized_slice_refreshes_via_chunked_delta() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        // A shard slice whose full SnapshotSlice payload exceeds the 64 MiB
        // frame cap used to poison the stream with FrameError::TooLarge
        // mid-run. It must now arrive as multiple chunked SnapshotDelta
        // frames and reconstruct bitwise.
        let dim = crate::transport::frame::MAX_PAYLOAD / 4 + 1;
        let theta: Vec<f32> = (0..dim as u32)
            .map(|i| f32::from_bits(i.wrapping_mul(0x9E37_79B9) >> 1))
            .collect();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = format!("{}", listener.local_addr().unwrap());
        let (grad_tx, _grad_rx) = mpsc::channel();
        let (_reply_tx, reply_rx) = mpsc::channel();
        let cells = vec![Arc::new(SnapshotCell::new(theta.clone()))];
        let stop = Arc::new(AtomicBool::new(false));
        // Moving ~67 MiB through framing + CRC needs more than the quick
        // heartbeat budget in debug builds.
        let net = NetOptions {
            hb_timeout: Duration::from_secs(60),
            ..quick_net()
        };
        let frontend = ThreadedFrontend::start(
            listener,
            ShardLayout::new(dim, 1),
            vec![grad_tx],
            cells,
            vec![reply_rx],
            vec![false],
            Arc::clone(&stop),
            net.clone(),
            false,
            None,
            None,
        )
        .unwrap();
        let mut t = TcpTransport::connect(&addr, "dense", net).unwrap();
        let mut out = vec![0.0f32; dim];
        let v = t.refresh(0, &mut out).unwrap();
        assert_eq!(v, 0);
        for (i, (a, b)) in out.iter().zip(&theta).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "elem {i}");
        }
        // The pull crossed the old single-frame ceiling, in pieces.
        let pulled = t.refresh_wire_bytes().unwrap();
        assert!(
            pulled as usize > crate::transport::frame::MAX_PAYLOAD,
            "chunked refresh moved {pulled} B"
        );
        drop(t);
        frontend.shutdown();
    }

    #[test]
    fn status_endpoint_answers_without_taking_a_slot() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, _grad_rxs, _reply_txs, _stop) = spawn_frontend(1);
        // A pre-attach probe answers on the handshake path...
        let doc = query_status(&addr, &quick_net()).unwrap();
        let json = crate::util::json::parse(&doc).expect("status must parse");
        assert_eq!(
            json.get("frontend").and_then(|j| j.as_str()),
            Some("threaded")
        );
        let workers = json.get("workers").expect("workers object");
        assert_eq!(workers.get("slots").and_then(|j| j.as_f64()), Some(1.0));
        assert_eq!(workers.get("active").and_then(|j| j.as_f64()), Some(0.0));
        // ...without consuming the single worker slot:
        let t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(t.attach_info().worker, 0);
        // A mid-run probe sees the attached worker; gradient counters
        // stay untouched by status traffic.
        let doc = query_status(&addr, &quick_net()).unwrap();
        assert_eq!(
            crate::util::json::scan_path(&doc, "workers.active").unwrap(),
            Some(crate::util::json::Json::Num(1.0)),
        );
        let stats = frontend.stats();
        assert_eq!(stats.grad_frame_bytes, 0);
        assert_eq!(stats.submissions, 0);
        drop(t);
        frontend.shutdown();
    }

    #[test]
    fn follow_status_streams_deltas_that_match_a_poll() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, _grad_rxs, _reply_txs, _stop) = spawn_frontend(1);
        let mut seqs = Vec::new();
        let mut docs = Vec::new();
        follow_status(&addr, &quick_net(), 20, |seq, json| {
            seqs.push(seq);
            docs.push(json.to_string());
            docs.len() < 3
        })
        .unwrap();
        assert_eq!(seqs, vec![0, 1, 2]);
        // Pushed deltas are the same document a poll would have produced
        // at that instant: same renderer, same fields.
        let polled = query_status(&addr, &quick_net()).unwrap();
        let polled = crate::util::json::parse(&polled).unwrap();
        for doc in &docs {
            let json = crate::util::json::parse(doc).expect("delta must parse");
            assert_eq!(
                json.get("frontend").and_then(|j| j.as_str()),
                polled.get("frontend").and_then(|j| j.as_str()),
            );
            assert_eq!(
                json.get("workers").and_then(|w| w.get("slots")).and_then(|j| j.as_f64()),
                polled.get("workers").and_then(|w| w.get("slots")).and_then(|j| j.as_f64()),
            );
        }
        // The follower never consumed the worker slot.
        let t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(t.attach_info().worker, 0);
        drop(t);
        frontend.shutdown();
    }

    #[test]
    fn second_worker_gets_next_slot_and_extra_attach_is_refused() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, _grad_rxs, _reply_txs, _stop) = spawn_frontend(2);
        let t0 = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        let t1 = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(t0.attach_info().worker, 0);
        assert_eq!(t1.attach_info().worker, 1);
        assert_eq!(frontend.active_conns(), 2);
        // a third attach has no slot: the server refuses politely
        let err = TcpTransport::connect(&addr, "dense", quick_net());
        assert!(err.is_err());
        drop(t0);
        drop(t1);
        frontend.shutdown();
    }

    #[test]
    fn geometry_mismatched_payload_drops_the_connection_not_the_server() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, grad_rxs, _reply_txs, _stop) = spawn_frontend(2);
        // Raw misbehaving client: handshake by hand, then submit a sparse
        // payload whose declared dim (and index) belong to a much larger
        // shard than the server's 2-coordinate shard 0. Decode alone cannot
        // catch this (indices are in range of the *declared* dim); the
        // server-side geometry check must, or the shard thread would panic
        // on the out-of-bounds scatter-add and abort the whole run.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut reader = FrameReader::new();
        let mut payload = Vec::new();
        let mut msg_buf = Vec::new();
        let mut frame_buf = Vec::new();
        Msg::Hello {
            worker: WORKER_UNASSIGNED,
            shards: 0,
            wire: "dense".into(),
        }
        .encode_into(&mut msg_buf).unwrap();
        encode_frame_into(&msg_buf, &mut frame_buf);
        s.write_all(&frame_buf).unwrap();
        let deadline = Instant::now() + Duration::from_secs(3);
        let welcome = read_msg_blocking(&mut s, &mut reader, &mut payload, deadline).unwrap();
        assert!(matches!(welcome, Msg::Welcome { .. }));
        let evil = ShardGrad::Sparse(Arc::new(crate::coordinator::compress::SparseGrad {
            dim: 1000,
            idx: vec![999],
            val: vec![1.0],
        }));
        encode_submit_into(0, 0, 0, 0.0, &evil, 0..1000, &mut msg_buf).unwrap();
        frame_buf.clear();
        encode_frame_into(&msg_buf, &mut frame_buf);
        s.write_all(&frame_buf).unwrap();
        // Nothing reaches the shard channel...
        assert!(grad_rxs[0].recv_timeout(Duration::from_millis(300)).is_err());
        // ...and the frontend survives: a well-formed worker still attaches
        // and its submissions flow.
        let mut t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        t.submit(
            0,
            ShardMsg {
                worker: 0,
                base_version: 0,
                loss: 0.0,
                grad: ShardGrad::Dense(Arc::new(vec![1.0, 2.0, 3.0, 4.0])),
                enq_ns: 0,
            },
        )
        .unwrap();
        let msg = recv_grad(&grad_rxs[0], Duration::from_secs(2));
        let mut got = vec![0.0f32; 2];
        msg.grad.view(0..2).add_to(&mut got);
        assert_eq!(got, vec![1.0, 2.0]);
        drop(t);
        drop(s);
        frontend.shutdown();
    }

    #[test]
    fn connect_backs_off_until_the_server_appears() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        // Reserve a port, release it, start the server 150 ms later.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = format!("{}", probe.local_addr().unwrap());
        drop(probe);
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            let listener = TcpListener::bind(&addr2).unwrap();
            let layout = ShardLayout::new(2, 1);
            let (gtx, _grx) = mpsc::channel();
            let (_rtx, rrx) = mpsc::channel::<Reply>();
            let stop = Arc::new(AtomicBool::new(false));
            let f = ThreadedFrontend::start(
                listener,
                layout,
                vec![gtx],
                vec![Arc::new(SnapshotCell::new(vec![0.0, 0.0]))],
                vec![rrx],
                vec![false],
                Arc::clone(&stop),
                quick_net(),
                false,
                None,
                None,
            )
            .unwrap();
            std::thread::sleep(Duration::from_millis(400));
            f.shutdown();
        });
        let t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(t.attach_info().worker, 0);
        drop(t);
        server.join().unwrap();
    }

    #[test]
    fn half_open_peer_is_detected_by_heartbeat_timeout() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        // A raw listener that accepts, answers the handshake, then goes
        // silent forever (no heartbeats): the client must detect the
        // half-open connection and report it (reconnect fails: the fake
        // server accepts no second handshake).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = format!("{}", listener.local_addr().unwrap());
        let silent = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // read the Hello, answer Welcome, then never write again
            let mut reader = FrameReader::new();
            let mut payload = Vec::new();
            let deadline = Instant::now() + Duration::from_secs(3);
            let _hello = read_msg_blocking(&mut s, &mut reader, &mut payload, deadline).unwrap();
            let mut msg_buf = Vec::new();
            let mut frame_buf = Vec::new();
            Msg::Welcome {
                worker: 0,
                workers: 1,
                shards: 1,
                dim: 2,
                delayed: false,
            }
            .encode_into(&mut msg_buf).unwrap();
            encode_frame_into(&msg_buf, &mut frame_buf);
            s.write_all(&frame_buf).unwrap();
            // hold the socket open, silently, long enough to trip the
            // client's heartbeat timeout
            std::thread::sleep(Duration::from_millis(900));
        });
        let mut net = quick_net();
        net.hb_timeout = Duration::from_millis(300);
        let mut t = TcpTransport::connect(&addr, "dense", net).unwrap();
        // wait past the timeout; the reader thread marks the conn dead
        let start = Instant::now();
        let mut saw_loss = false;
        while start.elapsed() < Duration::from_secs(3) {
            match t.recv_reply(Duration::from_millis(100)) {
                Err(TransportError::Timeout) => continue,
                Err(TransportError::Reconnected) | Err(TransportError::Closed(_)) => {
                    saw_loss = true;
                    break;
                }
                Ok(r) => panic!("unexpected reply {r:?}"),
            }
        }
        assert!(saw_loss, "half-open connection was never detected");
        silent.join().unwrap();
    }

    #[test]
    fn reconnect_reattaches_the_same_slot() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, grad_rxs, _reply_txs, _stop) = spawn_frontend(1);
        let mut net = quick_net();
        net.hb_timeout = Duration::from_millis(300);
        net.reconnect_attempts = 10;
        let mut t = TcpTransport::connect(&addr, "dense", net).unwrap();
        assert_eq!(t.attach_info().worker, 0);
        // Kill the connection from the client side's socket (simulates a
        // network drop): shut down the underlying stream out from under
        // the transport.
        t.conn
            .write
            .lock()
            .unwrap()
            .shutdown(std::net::Shutdown::Both)
            .unwrap();
        // The next operation reports the loss after transparently
        // redialing; the slot frees once the server reaps the old
        // connection, so allow a few rounds.
        let start = Instant::now();
        let mut reconnected = false;
        while start.elapsed() < Duration::from_secs(5) {
            match t.recv_reply(Duration::from_millis(50)) {
                Err(TransportError::Reconnected) => {
                    reconnected = true;
                    break;
                }
                Err(TransportError::Timeout) => {}
                Err(TransportError::Closed(why)) => panic!("gave up: {why}"),
                Ok(r) => panic!("unexpected reply {r:?}"),
            }
        }
        assert!(reconnected, "transport never reconnected");
        assert_eq!(t.attach_info().worker, 0, "slot changed across reconnect");
        // The re-attached connection still works end to end.
        t.submit(
            0,
            ShardMsg {
                worker: 0,
                base_version: 0,
                loss: 0.0,
                grad: ShardGrad::Dense(Arc::new(vec![1.0, 2.0, 3.0, 4.0])),
                enq_ns: 0,
            },
        )
        .unwrap();
        let msg = recv_grad(&grad_rxs[0], Duration::from_secs(2));
        assert_eq!(msg.worker, 0);
        drop(t);
        frontend.shutdown();
    }

    /// Attach with retry: a slot freed by a departure reopens within one
    /// teardown (~the poll granularity), but a dial can race it — retry a
    /// refused attach briefly instead of flaking.
    fn connect_when_slot_frees(addr: &str, net: NetOptions) -> TcpTransport {
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match TcpTransport::connect(addr, "dense", net.clone()) {
                Ok(t) => return t,
                Err(e) => {
                    assert!(Instant::now() < deadline, "slot never freed: {e:#}");
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        }
    }

    /// Raw handshake helper for the elastic tests: dial, send `Hello`,
    /// return the stream and the server's reply.
    fn raw_attach(addr: &str, worker: u32) -> (TcpStream, Msg) {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut reader = FrameReader::new();
        let mut payload = Vec::new();
        let mut msg_buf = Vec::new();
        let mut frame_buf = Vec::new();
        Msg::Hello {
            worker,
            shards: 0,
            wire: "dense".into(),
        }
        .encode_into(&mut msg_buf).unwrap();
        encode_frame_into(&msg_buf, &mut frame_buf);
        s.write_all(&frame_buf).unwrap();
        let deadline = Instant::now() + Duration::from_secs(3);
        let reply = read_msg_blocking(&mut s, &mut reader, &mut payload, deadline).unwrap();
        (s, reply)
    }

    #[test]
    fn elastic_attach_and_clean_leave_announce_membership_to_every_shard() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, grad_rxs, _reply_txs, _stop) = spawn_frontend_opts(2, true);
        let t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(t.attach_info().worker, 0);
        // The attach is announced as a Join on every shard channel.
        for rx in &grad_rxs {
            assert_eq!(recv_membership(rx, Duration::from_secs(2)), (true, 0));
        }
        // Dropping the transport sends a clean `Leave` frame: the shard
        // servers hear about the departure without waiting out the
        // heartbeat timeout.
        drop(t);
        for rx in &grad_rxs {
            assert_eq!(recv_membership(rx, Duration::from_secs(2)), (false, 0));
        }
        // The slot reopened: a replacement attaches as worker 0 again.
        let t2 = connect_when_slot_frees(&addr, quick_net());
        assert_eq!(t2.attach_info().worker, 0);
        for rx in &grad_rxs {
            assert_eq!(recv_membership(rx, Duration::from_secs(2)), (true, 0));
        }
        drop(t2);
        frontend.shutdown();
    }

    #[test]
    fn half_open_worker_parked_at_a_barrier_is_evicted_after_heartbeat_timeout() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        // The ISSUE-5 unit case: a worker attaches, submits one gradient
        // (server-side it may now be parked at a barrier), then goes
        // silent — no heartbeats, socket held open (half-open). The
        // frontend must evict it (Leave event to every shard) after the
        // heartbeat timeout instead of waiting on it forever.
        let (frontend, addr, grad_rxs, _reply_txs, _stop) = spawn_frontend_opts(1, true);
        let (mut s, reply) = raw_attach(&addr, WORKER_UNASSIGNED);
        assert!(matches!(reply, Msg::Welcome { worker: 0, .. }));
        assert_eq!(
            recv_membership(&grad_rxs[0], Duration::from_secs(2)),
            (true, 0)
        );
        // One submission, then silence.
        let mut msg_buf = Vec::new();
        let mut frame_buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.5,
            &ShardGrad::Dense(Arc::new(vec![1.0, 2.0, 3.0, 4.0])),
            0..2,
            &mut msg_buf,
        )
        .unwrap();
        // encode_submit_into fills msg_buf with the message; frame it.
        encode_frame_into(&msg_buf, &mut frame_buf);
        s.write_all(&frame_buf).unwrap();
        let grad = recv_grad(&grad_rxs[0], Duration::from_secs(2));
        assert_eq!(grad.worker, 0);
        // No heartbeats from us: the server declares the connection
        // half-open after its 400 ms quick_net timeout and evicts.
        let start = Instant::now();
        let (join, worker) = recv_membership(&grad_rxs[0], Duration::from_secs(5));
        assert!(!join, "expected an eviction Leave, got a Join");
        assert_eq!(worker, 0);
        assert!(
            start.elapsed() >= Duration::from_millis(200),
            "evicted before the heartbeat timeout could plausibly elapse"
        );
        // The reopened slot admits a replacement while the zombie socket
        // is still open.
        let t = connect_when_slot_frees(&addr, quick_net());
        assert_eq!(t.attach_info().worker, 0);
        drop(t);
        drop(s);
        frontend.shutdown();
    }

    #[test]
    fn zombie_reattach_to_a_reassigned_slot_is_evicted_not_retried() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        // One slot, elastic. The original worker departs (vacating the
        // slot) and a replacement (fresh attach) takes it; the previous
        // owner redialing under its old id must get a terminal Evict —
        // not the retryable Shutdown refusal.
        let (frontend, addr, _grad_rxs, _reply_txs, _stop) = spawn_frontend_opts(1, true);
        let original = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(original.attach_info().worker, 0);
        drop(original); // vacates the slot (clean Leave)
        let replacement = connect_when_slot_frees(&addr, quick_net());
        assert_eq!(replacement.attach_info().worker, 0);
        let (_s, reply) = raw_attach(&addr, 0);
        assert!(
            matches!(reply, Msg::Evict { worker: 0 }),
            "expected Evict, got {reply:?}"
        );
        drop(replacement);
        frontend.shutdown();
    }

    #[test]
    fn first_blip_named_redial_is_retryable_even_under_elastic() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        // A worker whose very first connection is still attached (e.g.
        // half-open, not yet reaped) redials under its assigned id. The
        // slot was never vacated, so this is plausibly the worker's own
        // connection: the refusal must stay the retryable Shutdown — an
        // Evict here would turn every transient blip into a dead worker.
        let (frontend, addr, _grad_rxs, _reply_txs, _stop) = spawn_frontend_opts(1, true);
        let holder = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(holder.attach_info().worker, 0);
        let (_s, reply) = raw_attach(&addr, 0);
        assert!(
            matches!(reply, Msg::Shutdown),
            "expected a retryable Shutdown, got {reply:?}"
        );
        drop(holder);
        frontend.shutdown();
    }

    #[test]
    fn static_frontend_still_refuses_with_retryable_shutdown() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        // elastic off: the busy-slot refusal stays a Shutdown (the
        // reconnect path depends on retrying through it) and no membership
        // events reach the shard channels.
        let (frontend, addr, grad_rxs, _reply_txs, _stop) = spawn_frontend(1);
        let holder = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(holder.attach_info().worker, 0);
        let (_s, reply) = raw_attach(&addr, 0);
        assert!(matches!(reply, Msg::Shutdown), "expected Shutdown, got {reply:?}");
        assert!(
            grad_rxs[0].try_recv().is_err(),
            "static frontend must not emit membership events"
        );
        drop(holder);
        frontend.shutdown();
    }
}
