//! The length-prefixed, versioned binary frame codec.
//!
//! Every message that crosses a process boundary travels inside one frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic   = b"HSGD"       (stream resync / protocol guard)
//! 4       1     version = FRAME_VERSION (incompatible layouts bump this)
//! 5       4     payload length, u32 LE  (≤ MAX_PAYLOAD)
//! 9       len   payload                 (an encoded `super::msg::Msg`)
//! 9+len   4     CRC32 (IEEE), u32 LE, over bytes [4, 9+len)
//! ```
//!
//! The CRC covers version + length + payload — everything after the magic —
//! so a bit flip anywhere in a frame is caught either structurally (magic /
//! version / length bounds) or by the checksum. Decoding is strict: a
//! truncated buffer, a wrong magic, an unsupported version, an absurd
//! length and a checksum mismatch each produce a distinct typed
//! [`FrameError`]; nothing panics on arbitrary input (fuzzed in
//! `tests/property_transport.rs`).
//!
//! Encode and decode both work against caller-owned buffers so the steady
//! state allocates nothing — the same recycling discipline as
//! [`crate::coordinator::compress::GradEncoder`].

use std::fmt;

/// Frame magic: ASCII "HSGD".
pub const MAGIC: [u8; 4] = *b"HSGD";

/// Current frame-layout version. Decoders accept exactly this version;
/// compatibility rules are documented in DESIGN.md §2.6.
pub const FRAME_VERSION: u8 = 1;

/// Bytes of framing around a payload: magic (4) + version (1) + length (4)
/// + CRC32 trailer (4).
pub const FRAME_OVERHEAD: usize = HEADER_LEN + TRAILER_LEN;

/// Frame header length (magic + version + payload length).
pub const HEADER_LEN: usize = 9;

/// Frame trailer length (CRC32).
pub const TRAILER_LEN: usize = 4;

/// Upper bound on a payload. Large enough for a 4 MB gradient frame with
/// room to spare; small enough that a corrupt length field cannot make a
/// reader attempt a multi-gigabyte allocation.
pub const MAX_PAYLOAD: usize = 64 * 1024 * 1024;

/// Everything that can be wrong with an incoming frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Fewer bytes than a complete frame; `need` is the total length the
    /// header (or minimum header size) implies, `have` what arrived.
    Truncated { need: usize, have: usize },
    /// The first four bytes are not [`MAGIC`].
    BadMagic { found: [u8; 4] },
    /// The version byte is not [`FRAME_VERSION`].
    Version { found: u8, supported: u8 },
    /// The length field exceeds [`MAX_PAYLOAD`].
    TooLarge { len: usize, max: usize },
    /// The stored CRC32 does not match the computed one.
    Corrupt { stored: u32, computed: u32 },
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            FrameError::BadMagic { found } => write!(f, "bad frame magic {found:02x?}"),
            FrameError::Version { found, supported } => {
                write!(f, "frame version {found} (this build speaks {supported})")
            }
            FrameError::TooLarge { len, max } => {
                write!(f, "frame payload of {len} bytes exceeds the {max} B cap")
            }
            FrameError::Corrupt { stored, computed } => write!(
                f,
                "frame CRC mismatch: stored {stored:08x}, computed {computed:08x}"
            ),
        }
    }
}

impl std::error::Error for FrameError {}

/// CRC32 (IEEE 802.3, reflected, polynomial 0xEDB88320) lookup table,
/// built at compile time — hand-rolled, no crates.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 of a byte slice (init 0xFFFFFFFF, final xor 0xFFFFFFFF — the
/// standard IEEE parameters, so `crc32(b"123456789") == 0xCBF43926`).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Append one complete frame around `payload` to `out` (which is *not*
/// cleared — callers batch frames into one write buffer). Reuses `out`'s
/// capacity; zero allocations once warm.
///
/// Panics if `payload` exceeds [`MAX_PAYLOAD`] — encoders control their
/// payload sizes, so an oversized one is a programming error, not an I/O
/// condition.
pub fn encode_frame_into(payload: &[u8], out: &mut Vec<u8>) {
    assert!(
        payload.len() <= MAX_PAYLOAD,
        "frame payload of {} bytes exceeds MAX_PAYLOAD",
        payload.len()
    );
    let start = out.len();
    out.extend_from_slice(&MAGIC);
    out.push(FRAME_VERSION);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    let crc = crc32(&out[start + 4..]);
    out.extend_from_slice(&crc.to_le_bytes());
}

/// Total bytes on the wire for a payload of `payload_len` bytes.
pub fn frame_len(payload_len: usize) -> usize {
    payload_len + FRAME_OVERHEAD
}

/// Decode the frame at the start of `buf`. Returns the payload slice and
/// the total number of bytes the frame occupies. Every malformed input —
/// including a buffer truncated at *any* byte offset — yields a typed
/// [`FrameError`]; this function never panics and never returns a payload
/// whose checksum did not verify.
pub fn decode_frame(buf: &[u8]) -> Result<(&[u8], usize), FrameError> {
    if buf.len() < HEADER_LEN {
        // Not enough even for the header. Check what we do have so a wrong
        // protocol is reported as BadMagic rather than an eternal
        // "need more bytes".
        if buf.len() >= 4 && buf[..4] != MAGIC {
            let mut found = [0u8; 4];
            found.copy_from_slice(&buf[..4]);
            return Err(FrameError::BadMagic { found });
        }
        return Err(FrameError::Truncated {
            need: HEADER_LEN,
            have: buf.len(),
        });
    }
    if buf[..4] != MAGIC {
        let mut found = [0u8; 4];
        found.copy_from_slice(&buf[..4]);
        return Err(FrameError::BadMagic { found });
    }
    if buf[4] != FRAME_VERSION {
        return Err(FrameError::Version {
            found: buf[4],
            supported: FRAME_VERSION,
        });
    }
    let len = u32::from_le_bytes([buf[5], buf[6], buf[7], buf[8]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(FrameError::TooLarge {
            len,
            max: MAX_PAYLOAD,
        });
    }
    let total = frame_len(len);
    if buf.len() < total {
        return Err(FrameError::Truncated {
            need: total,
            have: buf.len(),
        });
    }
    let stored = u32::from_le_bytes([
        buf[total - 4],
        buf[total - 3],
        buf[total - 2],
        buf[total - 1],
    ]);
    let computed = crc32(&buf[4..total - 4]);
    if stored != computed {
        return Err(FrameError::Corrupt { stored, computed });
    }
    Ok((&buf[HEADER_LEN..total - 4], total))
}

/// Incremental frame reader over a byte stream (the TCP receive path).
///
/// Owns an accumulation buffer; [`FrameReader::feed`] appends raw bytes,
/// [`FrameReader::next_frame`] pops the next complete frame's payload into
/// a caller buffer (reused across frames — no steady-state allocation).
/// Structural errors are *not* recoverable: a stream that produced a bad
/// magic or CRC is desynchronized and must be dropped (the TCP layer closes
/// the connection), so the reader stays poisoned after the first error.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// Bytes at the front of `buf` already consumed (compacted lazily).
    consumed: usize,
    /// First structural error seen; replayed on every later call.
    poisoned: Option<FrameError>,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Append raw bytes received from the stream.
    pub fn feed(&mut self, bytes: &[u8]) {
        // Compact before growing: keeps the buffer bounded by one frame
        // plus one read's worth of bytes.
        if self.consumed > 0 {
            self.buf.drain(..self.consumed);
            self.consumed = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered and not yet decoded.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.consumed
    }

    /// Pop the next complete frame, writing its payload into `payload`
    /// (cleared and refilled). `Ok(true)` = one frame decoded; `Ok(false)`
    /// = need more bytes; `Err` = the stream is corrupt (poisoned
    /// thereafter).
    pub fn next_frame(&mut self, payload: &mut Vec<u8>) -> Result<bool, FrameError> {
        if let Some(e) = &self.poisoned {
            return Err(e.clone());
        }
        match decode_frame(&self.buf[self.consumed..]) {
            Ok((p, total)) => {
                payload.clear();
                payload.extend_from_slice(p);
                self.consumed += total;
                Ok(true)
            }
            Err(FrameError::Truncated { .. }) => Ok(false),
            Err(e) => {
                self.poisoned = Some(e.clone());
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_and_exact_overhead() {
        let payload = b"hello gradient".to_vec();
        let mut out = Vec::new();
        encode_frame_into(&payload, &mut out);
        assert_eq!(out.len(), payload.len() + FRAME_OVERHEAD);
        let (got, consumed) = decode_frame(&out).unwrap();
        assert_eq!(got, &payload[..]);
        assert_eq!(consumed, out.len());
        // empty payloads are legal (Heartbeat/Shutdown are tiny)
        let mut out2 = Vec::new();
        encode_frame_into(&[], &mut out2);
        let (got2, c2) = decode_frame(&out2).unwrap();
        assert!(got2.is_empty());
        assert_eq!(c2, FRAME_OVERHEAD);
    }

    #[test]
    fn truncation_at_every_offset_is_typed() {
        let mut out = Vec::new();
        encode_frame_into(b"0123456789abcdef", &mut out);
        for cut in 0..out.len() {
            match decode_frame(&out[..cut]) {
                Err(FrameError::Truncated { need, have }) => {
                    assert_eq!(have, cut);
                    assert!(need > cut);
                }
                other => panic!("cut at {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn corruption_magic_version_length_crc() {
        let mut out = Vec::new();
        encode_frame_into(b"payload", &mut out);
        // magic
        let mut bad = out.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(decode_frame(&bad), Err(FrameError::BadMagic { .. })));
        // version
        let mut bad = out.clone();
        bad[4] = FRAME_VERSION + 1;
        assert_eq!(
            decode_frame(&bad),
            Err(FrameError::Version {
                found: FRAME_VERSION + 1,
                supported: FRAME_VERSION
            })
        );
        // absurd length
        let mut bad = out.clone();
        bad[5..9].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(decode_frame(&bad), Err(FrameError::TooLarge { .. })));
        // payload flip → CRC catches it
        let mut bad = out.clone();
        bad[HEADER_LEN] ^= 0x01;
        assert!(matches!(decode_frame(&bad), Err(FrameError::Corrupt { .. })));
        // CRC flip → CRC catches it
        let mut bad = out.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x80;
        assert!(matches!(decode_frame(&bad), Err(FrameError::Corrupt { .. })));
    }

    #[test]
    fn reader_reassembles_split_frames() {
        let mut wire = Vec::new();
        encode_frame_into(b"first", &mut wire);
        encode_frame_into(b"second", &mut wire);
        let mut r = FrameReader::new();
        let mut payload = Vec::new();
        // drip-feed one byte at a time; exactly two frames must pop out
        let mut seen = Vec::new();
        for &b in &wire {
            r.feed(&[b]);
            while r.next_frame(&mut payload).unwrap() {
                seen.push(payload.clone());
            }
        }
        assert_eq!(seen, vec![b"first".to_vec(), b"second".to_vec()]);
        assert_eq!(r.pending(), 0);
    }

    #[test]
    fn reader_poisons_on_corrupt_stream() {
        let mut wire = Vec::new();
        encode_frame_into(b"data", &mut wire);
        wire[HEADER_LEN] ^= 0xFF; // corrupt the payload
        let mut r = FrameReader::new();
        r.feed(&wire);
        let mut payload = Vec::new();
        assert!(r.next_frame(&mut payload).is_err());
        // stays in the error state even if good bytes follow
        let mut good = Vec::new();
        encode_frame_into(b"ok", &mut good);
        r.feed(&good);
        assert!(r.next_frame(&mut payload).is_err());
    }

    #[test]
    fn reader_rejects_foreign_protocol_early() {
        let mut r = FrameReader::new();
        r.feed(b"GET / HTTP/1.1\r\n");
        let mut payload = Vec::new();
        assert!(matches!(
            r.next_frame(&mut payload),
            Err(FrameError::BadMagic { .. })
        ));
    }
}
