//! Event-driven serving frontend: one nonblocking reactor thread owns the
//! acceptor and every worker connection.
//!
//! The threaded frontend ([`super::tcp::ThreadedFrontend`]) spends three
//! blocking threads per connection (frame reader, frame writer, reply
//! pump), each waking on a 25 ms poll slice. That is fine for 8 workers
//! and fatal for the serving story's connection counts: thread stacks,
//! context switches and the per-slice wakeups all scale linearly with the
//! worker count. This module replaces them with a single poll loop:
//!
//! - **Readiness**: every socket is `set_nonblocking`; one level-triggered
//!   `poll(2)` call (a hand-rolled FFI-free syscall shim on Linux
//!   x86_64/aarch64, a short-nap mark-all-ready fallback elsewhere) waits
//!   on the acceptor, a wakeup pipe and all connections at once.
//! - **Per-connection state machines**: partial-frame reads accumulate in
//!   the connection's [`FrameReader`]; outbound frames append into pooled
//!   buffers on a write queue and many small `GradAck` / `Heartbeat` /
//!   `SnapshotSlice` frames leave in one `write_vectored` call.
//! - **Timers**: heartbeat emission and liveness eviction (which also
//!   bounds the handshake and the refusal-drain) live on a deadline heap
//!   with generation-checked lazy invalidation, so teardown latency is
//!   bounded by the timer resolution, not thread-join races.
//! - **Reply wakeups**: shard servers call the frontend's reply notifier
//!   after each reply send; the notifier writes one byte into a loopback
//!   wakeup socket, so acks leave within one reactor iteration instead of
//!   a blocking pump's poll slice. Without a notifier installed the
//!   reactor degrades to a 5 ms reply tick.
//!
//! **Wire-bytes invariant**: everything observable on the wire — message
//! set, frame layout, handshake/refusal classification, byte accounting,
//! elastic Join/Leave ordering — is identical to the threaded frontend;
//! only scheduling differs. The single deliberate divergence: liveness is
//! measured from the last *complete frame*, not the last byte, so a
//! slow-loris peer trickling bytes forever is still evicted at the
//! heartbeat timeout (the threaded reader counted raw bytes). See
//! DESIGN.md §2.8.

use super::frame::{encode_frame_into, FrameReader, FRAME_OVERHEAD};
use super::msg::{
    encode_snapshot_slice_into, snapshot_response_msgs, snapshot_serves_full, Msg,
    WORKER_UNASSIGNED,
};
use super::tcp::{FrontendStats, NetOptions};
use crate::coordinator::compress::ShardGrad;
use crate::coordinator::params::SnapshotCell;
use crate::coordinator::server::{Reply, ShardEvent, ShardMsg, StatusBoard};
use crate::coordinator::shard::ShardLayout;
use crate::log_warn;
use crate::util::trace::{Stage, TraceRing};
use std::collections::{BinaryHeap, VecDeque};
use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Outbound coalescing buffer target: frames append into the queue's tail
/// buffer until it reaches this size, then a fresh pooled buffer starts.
/// One oversized frame (a big `SnapshotSlice`) still lands in one buffer.
const COALESCE_CAP: usize = 256 * 1024;
/// Upper bound on iovecs per `write_vectored` call (IOV_MAX is ≥ 1024
/// everywhere we run; 64 keeps the stack array small).
const MAX_IOVECS: usize = 64;
/// Reply-channel poll tick used only when no reply notifier is installed
/// (unit tests drive the slots' reply channels directly).
const REPLY_TICK: Duration = Duration::from_millis(5);
/// Poll timeout cap when nothing is due: the stop flag is delivered via
/// the waker, so this is a safety net, not a latency bound.
const IDLE_CAP: Duration = Duration::from_millis(500);
/// Reads per connection per iteration (× 64 KiB chunk): bounds how long
/// one firehose connection can monopolize the loop.
const READS_PER_CONN: usize = 8;

// ---------------------------------------------------------------------------
// poll(2) shim
// ---------------------------------------------------------------------------

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

/// `struct pollfd`, as the kernel ABI defines it.
#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

#[cfg(unix)]
fn raw_fd<T: std::os::fd::AsRawFd>(t: &T) -> i32 {
    t.as_raw_fd()
}

#[cfg(not(unix))]
fn raw_fd<T>(_t: &T) -> i32 {
    -1
}

/// Raw `poll(2)` on Linux x86_64 (syscall 7). The kernel writes `revents`,
/// so the asm block may not claim `nomem`.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sys_poll(fds: &mut [PollFd], timeout_ms: i32) -> isize {
    let mut ret: isize;
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") 7isize => ret,
            in("rdi") fds.as_mut_ptr(),
            in("rsi") fds.len(),
            in("rdx") timeout_ms as isize,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret
}

/// Raw `ppoll` on Linux aarch64 (syscall 73; plain `poll` does not exist
/// there). Linux may write back the remaining time, hence `&mut ts`.
#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sys_poll(fds: &mut [PollFd], timeout_ms: i32) -> isize {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    let mut ts = Timespec {
        tv_sec: i64::from(timeout_ms) / 1000,
        tv_nsec: (i64::from(timeout_ms) % 1000) * 1_000_000,
    };
    let mut ret: isize;
    unsafe {
        core::arch::asm!(
            "svc 0",
            inlateout("x0") fds.as_mut_ptr() => ret,
            in("x1") fds.len(),
            in("x2") &mut ts as *mut Timespec,
            in("x3") 0usize, // no signal mask
            in("x4") 8usize, // sigsetsize
            in("x8") 73usize,
            options(nostack),
        );
    }
    ret
}

/// Wait for readiness. Returns the number of ready fds (0 on timeout or
/// EINTR — both just mean "nothing to do yet", the loop re-derives its
/// state every iteration anyway).
fn poll_fds(fds: &mut [PollFd], timeout: Duration) -> usize {
    let mut ms = timeout.as_millis().min(60_000) as i32;
    if ms == 0 && !timeout.is_zero() {
        ms = 1; // never round a short wait down to a busy spin
    }
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let ret = sys_poll(fds, ms);
        if ret < 0 {
            0
        } else {
            ret as usize
        }
    }
    #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        // Portable fallback: a short nap, then report every fd ready. All
        // handlers tolerate `WouldBlock`, so spurious readiness is merely
        // inefficient (≤ 1 kHz of no-op syscalls), never incorrect.
        std::thread::sleep(Duration::from_millis(ms.clamp(0, 1) as u64));
        for f in fds.iter_mut() {
            f.revents = f.events;
        }
        fds.len()
    }
}

// ---------------------------------------------------------------------------
// waker
// ---------------------------------------------------------------------------

/// Cross-thread wakeup into the poll loop: a loopback TCP pair (std has no
/// portable pipe) plus a pending flag so back-to-back wakes cost one byte.
struct Waker {
    tx: Mutex<TcpStream>,
    pending: AtomicBool,
}

impl Waker {
    /// Build the pair; returns the waker and the reactor-held read end.
    fn pair() -> std::io::Result<(Arc<Waker>, TcpStream)> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let tx = TcpStream::connect(listener.local_addr()?)?;
        let (rx, _) = listener.accept()?;
        rx.set_nonblocking(true)?;
        tx.set_nonblocking(true)?;
        tx.set_nodelay(true).ok();
        Ok((
            Arc::new(Waker {
                tx: Mutex::new(tx),
                pending: AtomicBool::new(false),
            }),
            rx,
        ))
    }

    fn wake(&self) {
        if !self.pending.swap(true, Ordering::AcqRel) {
            let _ = self.tx.lock().unwrap().write(&[1u8]);
        }
    }
}

// ---------------------------------------------------------------------------
// buffer pool and timers
// ---------------------------------------------------------------------------

/// Recycled outbound buffers (the GradEncoder discipline: steady state
/// allocates nothing, capacity survives the round trip).
struct BufPool {
    free: Vec<Vec<u8>>,
}

impl BufPool {
    fn new() -> BufPool {
        BufPool { free: Vec::new() }
    }

    fn take(&mut self) -> Vec<u8> {
        match self.free.pop() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => Vec::new(),
        }
    }

    fn put(&mut self, buf: Vec<u8>) {
        if self.free.len() < 64 && buf.capacity() > 0 {
            self.free.push(buf);
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum TimerKind {
    /// Emit a heartbeat if the connection has been idle a full interval.
    Heartbeat,
    /// Evict if no complete frame arrived within the heartbeat timeout.
    /// Armed at accept, so it also bounds the handshake and the drain of a
    /// refused connection that never reads its refusal.
    Liveness,
    /// Push the next `StatusDelta` to a subscribed connection.
    StatusPush,
}

struct TimerEntry {
    at: Instant,
    conn: usize,
    gen: u64,
    kind: TimerKind,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEntry {
    // Reversed: BinaryHeap is a max-heap, we want the earliest deadline.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.at.cmp(&self.at)
    }
}

/// Deadline heap with generation-checked lazy invalidation: cancelling is
/// free (the connection's generation moved on), firing checks it.
struct TimerWheel {
    heap: BinaryHeap<TimerEntry>,
}

impl TimerWheel {
    fn new() -> TimerWheel {
        TimerWheel {
            heap: BinaryHeap::new(),
        }
    }

    fn arm(&mut self, at: Instant, conn: usize, gen: u64, kind: TimerKind) {
        self.heap.push(TimerEntry { at, conn, gen, kind });
    }

    fn next_deadline(&self) -> Option<Instant> {
        self.heap.peek().map(|e| e.at)
    }

    fn pop_due(&mut self, now: Instant) -> Option<TimerEntry> {
        if self.heap.peek().map_or(false, |e| e.at <= now) {
            self.heap.pop()
        } else {
            None
        }
    }
}

// ---------------------------------------------------------------------------
// connection state machine
// ---------------------------------------------------------------------------

enum Phase {
    /// Accepted, no `Hello` yet: no slot, no worker identity.
    Handshake,
    /// Attached to worker slot `worker`; owns its reply channel.
    Attached { worker: usize },
    /// Refused: flush the queued refusal, then close.
    Draining,
}

struct Conn {
    stream: TcpStream,
    /// Generation for lazy timer invalidation (monotone per slab index).
    gen: u64,
    peer: String,
    phase: Phase,
    reader: FrameReader,
    /// Outbound coalescing queue; the front buffer may be partially
    /// written (`front_written` bytes already on the wire).
    outq: VecDeque<Vec<u8>>,
    front_written: usize,
    reply_rx: Option<Receiver<Reply>>,
    /// Arrival time of the last complete frame (liveness basis).
    last_frame: Instant,
    /// When the next idle heartbeat is due; pushed out by any queued frame.
    next_hb: Instant,
    hb_seq: u64,
    /// Active status subscription, if any: push interval and the sequence
    /// number of the next delta.
    sub: Option<Sub>,
}

/// Status-subscription state for one connection.
struct Sub {
    interval: Duration,
    seq: u64,
}

/// One worker slot — same fields and classification semantics as the
/// threaded frontend's, minus the mutex (the reactor thread is the only
/// accessor).
struct Slot {
    attached: bool,
    taken_as: u32,
    taken_after_vacancy: bool,
    vacancies: u64,
    reply_rx: Option<Receiver<Reply>>,
}

/// Counters shared between the reactor thread and the handle.
#[derive(Default)]
struct Counters {
    grad_frame_bytes: AtomicU64,
    submissions: AtomicU64,
    active_conns: AtomicUsize,
    ever_joined: AtomicUsize,
    /// A reply notifier was handed out: replies wake the loop, no tick.
    notifier_taken: AtomicBool,
}

// ---------------------------------------------------------------------------
// public handle
// ---------------------------------------------------------------------------

/// The event-driven serving frontend. Drop-in for the threaded one: same
/// `start` signature, same wire protocol, one thread total.
pub struct TcpFrontend {
    counters: Arc<Counters>,
    stop: Arc<AtomicBool>,
    waker: Arc<Waker>,
    thread: Option<JoinHandle<()>>,
}

impl TcpFrontend {
    /// Start serving. Arguments exactly as
    /// [`super::tcp::ThreadedFrontend::start`]; the frontend owns clones of
    /// the gradient senders and releases them on [`TcpFrontend::shutdown`].
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        listener: TcpListener,
        layout: ShardLayout,
        grad_txs: Vec<Sender<ShardEvent>>,
        cells: Vec<Arc<SnapshotCell>>,
        reply_rxs: Vec<Receiver<Reply>>,
        delayed: Vec<bool>,
        stop: Arc<AtomicBool>,
        net: NetOptions,
        elastic: bool,
        status: Option<Arc<StatusBoard>>,
        trace: Option<Arc<TraceRing>>,
    ) -> std::io::Result<TcpFrontend> {
        listener.set_nonblocking(true)?;
        let (waker, wake_rx) = Waker::pair()?;
        let counters = Arc::new(Counters::default());
        let slots = reply_rxs
            .into_iter()
            .map(|rx| Slot {
                attached: false,
                taken_as: WORKER_UNASSIGNED,
                taken_after_vacancy: false,
                vacancies: 0,
                reply_rx: Some(rx),
            })
            .collect();
        let reactor = Reactor {
            listener,
            wake_rx,
            waker: Arc::clone(&waker),
            layout,
            grad_txs,
            cells,
            slots,
            delayed,
            stop: Arc::clone(&stop),
            net,
            elastic,
            status,
            trace,
            started: Instant::now(),
            counters: Arc::clone(&counters),
            conns: Vec::new(),
            free: Vec::new(),
            next_gen: 0,
            timers: TimerWheel::new(),
            pool: BufPool::new(),
            chunk: vec![0u8; 64 * 1024],
            scratch: Vec::new(),
            payload: Vec::new(),
            pollfds: Vec::new(),
            poll_map: Vec::new(),
            now: Instant::now(),
        };
        let thread = std::thread::Builder::new()
            .name("tcp-reactor".into())
            .spawn(move || reactor.run())?;
        Ok(TcpFrontend {
            counters,
            stop,
            waker,
            thread: Some(thread),
        })
    }

    /// Workers currently connected.
    pub fn active_conns(&self) -> usize {
        self.counters.active_conns.load(Ordering::Relaxed)
    }

    /// Workers that have ever completed an attach.
    pub fn ever_joined(&self) -> usize {
        self.counters.ever_joined.load(Ordering::Relaxed)
    }

    /// Gradient-plane byte counters.
    pub fn stats(&self) -> FrontendStats {
        FrontendStats {
            grad_frame_bytes: self.counters.grad_frame_bytes.load(Ordering::Relaxed),
            submissions: self.counters.submissions.load(Ordering::Relaxed),
        }
    }

    /// A callback for the shard servers to invoke after sending a reply:
    /// wakes the reactor so the ack leaves immediately. Taking it disables
    /// the fallback reply tick.
    pub fn reply_notifier(&self) -> Arc<dyn Fn(usize) + Send + Sync> {
        self.counters.notifier_taken.store(true, Ordering::Relaxed);
        let waker = Arc::clone(&self.waker);
        Arc::new(move |_worker: usize| waker.wake())
    }

    /// Stop serving: live workers receive `Shutdown` (with a bounded flush
    /// grace), every connection is torn down, and the gradient senders are
    /// released so the shard servers drain and exit.
    pub fn shutdown(mut self) -> FrontendStats {
        self.stop.store(true, Ordering::Relaxed);
        self.waker.wake();
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
        self.stats()
    }
}

impl Drop for TcpFrontend {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.waker.wake();
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// the reactor
// ---------------------------------------------------------------------------

struct Reactor {
    listener: TcpListener,
    wake_rx: TcpStream,
    waker: Arc<Waker>,
    layout: ShardLayout,
    grad_txs: Vec<Sender<ShardEvent>>,
    cells: Vec<Arc<SnapshotCell>>,
    slots: Vec<Slot>,
    delayed: Vec<bool>,
    stop: Arc<AtomicBool>,
    net: NetOptions,
    elastic: bool,
    /// Per-shard live counters published by `run_shard` (the ops plane);
    /// `None` when serving without a status board (unit tests).
    status: Option<Arc<StatusBoard>>,
    /// Flight recorder for the gradient lifecycle; `None` keeps the hot
    /// path free of clock reads (`--trace` off).
    trace: Option<Arc<TraceRing>>,
    /// When serving began (uptime / bytes-per-second basis).
    started: Instant,
    counters: Arc<Counters>,
    /// Connection slab; `free` holds vacated indices for reuse.
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    next_gen: u64,
    timers: TimerWheel,
    pool: BufPool,
    /// Read scratch (one chunk for all connections — single-threaded).
    chunk: Vec<u8>,
    /// Message-encode scratch (body bytes, pre-framing).
    scratch: Vec<u8>,
    /// Frame-payload scratch for the incremental decoder.
    payload: Vec<u8>,
    pollfds: Vec<PollFd>,
    /// `pollfds[i + 2]` belongs to connection slab index `poll_map[i]`.
    poll_map: Vec<usize>,
    /// Refreshed once per iteration; all timer math uses it.
    now: Instant,
}

impl Reactor {
    fn run(mut self) {
        loop {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            let timeout = self.poll_timeout();
            self.build_pollfds();
            poll_fds(&mut self.pollfds, timeout);
            self.now = Instant::now();
            // Clear the wake flag *before* draining reply channels: a
            // notify arriving after the drain then lands a fresh byte and
            // the next poll returns immediately — no lost wakeups.
            self.drain_waker();
            self.accept_ready();
            let ready: Vec<usize> = self
                .poll_map
                .iter()
                .enumerate()
                .filter(|&(i, _)| {
                    self.pollfds[i + 2].revents & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0
                })
                .map(|(_, &idx)| idx)
                .collect();
            for idx in ready {
                self.service_read(idx);
            }
            self.drain_replies();
            self.fire_timers();
            self.flush_pass();
        }
        self.shutdown_conns();
        // Dropping `self` here releases `grad_txs`: the shard servers see
        // disconnection exactly as when in-process workers finish.
    }

    fn poll_timeout(&self) -> Duration {
        let now = Instant::now();
        let mut t = IDLE_CAP;
        if let Some(at) = self.timers.next_deadline() {
            t = t.min(at.saturating_duration_since(now));
        }
        let replies_possible = self.conns.iter().flatten().any(|c| c.reply_rx.is_some());
        if replies_possible && !self.counters.notifier_taken.load(Ordering::Relaxed) {
            t = t.min(REPLY_TICK);
        }
        t
    }

    fn build_pollfds(&mut self) {
        self.pollfds.clear();
        self.poll_map.clear();
        self.pollfds.push(PollFd {
            fd: raw_fd(&self.listener),
            events: POLLIN,
            revents: 0,
        });
        self.pollfds.push(PollFd {
            fd: raw_fd(&self.wake_rx),
            events: POLLIN,
            revents: 0,
        });
        for (idx, conn) in self.conns.iter().enumerate() {
            if let Some(c) = conn {
                let mut events = POLLIN;
                if !c.outq.is_empty() {
                    events |= POLLOUT;
                }
                self.pollfds.push(PollFd {
                    fd: raw_fd(&c.stream),
                    events,
                    revents: 0,
                });
                self.poll_map.push(idx);
            }
        }
    }

    fn drain_waker(&mut self) {
        self.waker.pending.store(false, Ordering::Release);
        let mut buf = [0u8; 64];
        loop {
            match self.wake_rx.read(&mut buf) {
                Ok(0) => break, // waker closed: shutdown imminent
                Ok(_) => {}
                Err(_) => break, // WouldBlock (or a real error: fatal later)
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return;
            }
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue; // cannot serve a blocking socket here
                    }
                    stream.set_nodelay(true).ok();
                    let gen = self.next_gen;
                    self.next_gen += 1;
                    let idx = match self.free.pop() {
                        Some(i) => i,
                        None => {
                            self.conns.push(None);
                            self.conns.len() - 1
                        }
                    };
                    self.conns[idx] = Some(Conn {
                        stream,
                        gen,
                        peer: peer.to_string(),
                        phase: Phase::Handshake,
                        reader: FrameReader::new(),
                        outq: VecDeque::new(),
                        front_written: 0,
                        reply_rx: None,
                        last_frame: self.now,
                        next_hb: self.now + self.net.hb_interval,
                        hb_seq: 0,
                        sub: None,
                    });
                    // One self-rearming liveness timer per connection: it
                    // bounds the handshake, steady-state silence and the
                    // refusal drain alike.
                    self.timers
                        .arm(self.now + self.net.hb_timeout, idx, gen, TimerKind::Liveness);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) => {
                    log_warn!("transport", "accept failed: {e}");
                    return;
                }
            }
        }
    }

    fn service_read(&mut self, idx: usize) {
        let Some(mut conn) = self.conns[idx].take() else {
            return;
        };
        match self.read_conn(&mut conn, idx) {
            Ok(()) => self.conns[idx] = Some(conn),
            Err(reason) => self.teardown(conn, idx, &reason),
        }
    }

    /// Read until `WouldBlock` (bounded by [`READS_PER_CONN`]), decoding
    /// and dispatching every complete frame. `Err` means close, with an
    /// empty reason for clean departures.
    fn read_conn(&mut self, conn: &mut Conn, idx: usize) -> Result<(), String> {
        for _ in 0..READS_PER_CONN {
            let n = match conn.stream.read(&mut self.chunk) {
                Ok(0) => return Err(String::new()), // peer closed
                Ok(n) => n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    return Ok(())
                }
                Err(e) => return Err(format!("read error: {e}")),
            };
            conn.reader.feed(&self.chunk[..n]);
            loop {
                match conn.reader.next_frame(&mut self.payload) {
                    Ok(true) => {
                        conn.last_frame = self.now;
                        self.on_frame(conn, idx)?;
                        if matches!(conn.phase, Phase::Draining) {
                            // Refused mid-stream: stop decoding, just drain.
                            return Ok(());
                        }
                    }
                    Ok(false) => break,
                    Err(e) => return Err(format!("dropping corrupt stream: {e}")),
                }
            }
        }
        Ok(())
    }

    fn on_frame(&mut self, conn: &mut Conn, idx: usize) -> Result<(), String> {
        let frame_bytes = (self.payload.len() + FRAME_OVERHEAD) as u64;
        let msg = Msg::decode(&self.payload).map_err(|e| format!("dropping corrupt stream: {e}"))?;
        match conn.phase {
            Phase::Handshake => self.on_hello(conn, idx, msg),
            Phase::Attached { worker } => self.on_worker_msg(conn, idx, worker, msg, frame_bytes),
            Phase::Draining => Ok(()), // refused peer still talking: ignore
        }
    }

    /// Slot assignment + Welcome, with the exact refusal classification of
    /// the threaded frontend (see the long comment in `tcp::handle_conn`):
    /// under elastic membership a fresh occupant on a previously vacated
    /// slot marks a named re-attach as terminally evicted; anything else
    /// refuses with the retryable `Shutdown`.
    fn on_hello(&mut self, conn: &mut Conn, idx: usize, msg: Msg) -> Result<(), String> {
        // A status probe never takes a worker slot: answer from the
        // handshake phase and leave the connection there (the probe closes
        // when it has read its document; liveness bounds a lingering one).
        if matches!(msg, Msg::StatusRequest) {
            let json = self.status_doc();
            self.queue(conn, &Msg::Status { json });
            return Ok(());
        }
        // A subscription likewise stays in the handshake phase: the
        // follower never takes a worker slot, it just receives pushed
        // deltas (and keeps itself alive with heartbeat frames).
        if let Msg::Subscribe { interval_ms } = msg {
            self.subscribe(conn, idx, interval_ms);
            return Ok(());
        }
        if conn.sub.is_some() && matches!(msg, Msg::Heartbeat { .. }) {
            return Ok(()); // follower keepalive
        }
        let (requested, wire) = match msg {
            Msg::Hello { worker, wire, .. } => (worker, wire),
            other => return Err(format!("expected Hello, got {other:?}")),
        };
        let mut evicted = false;
        let id = if requested == WORKER_UNASSIGNED {
            self.slots
                .iter()
                .position(|s| !s.attached && s.reply_rx.is_some())
        } else {
            let id = requested as usize;
            match self.slots.get(id) {
                Some(s) if !s.attached && s.reply_rx.is_some() => Some(id),
                Some(s) if s.attached => {
                    evicted = self.elastic
                        && s.taken_as == WORKER_UNASSIGNED
                        && s.taken_after_vacancy;
                    None
                }
                _ => None,
            }
        };
        let Some(id) = id else {
            let refusal = if evicted {
                Msg::Evict { worker: requested }
            } else {
                Msg::Shutdown
            };
            self.queue(conn, &refusal);
            conn.phase = Phase::Draining;
            return Ok(());
        };
        {
            let slot = &mut self.slots[id];
            slot.attached = true;
            slot.taken_as = requested;
            slot.taken_after_vacancy = slot.vacancies > 0;
            conn.reply_rx = Some(
                slot.reply_rx
                    .take()
                    .expect("attached slot lost its reply channel"),
            );
        }
        log_warn!(
            "transport",
            "worker {id} attached (wire={wire}, requested={})",
            if requested == WORKER_UNASSIGNED {
                "new".to_string()
            } else {
                requested.to_string()
            }
        );
        self.counters.active_conns.fetch_add(1, Ordering::Relaxed);
        self.counters.ever_joined.fetch_add(1, Ordering::Relaxed);
        // Welcome is queued before the reply channel is first drained, so
        // stale acks from a previous occupancy can never overtake it.
        self.queue(
            conn,
            &Msg::Welcome {
                worker: id as u32,
                workers: self.delayed.len() as u32,
                shards: self.layout.shards() as u32,
                dim: self.layout.dim() as u64,
                delayed: self.delayed[id],
            },
        );
        // Elastic: announce the attach to every shard before any of this
        // connection's gradients can reach them (same channel ⇒ FIFO).
        if self.elastic {
            for tx in &self.grad_txs {
                let _ = tx.send(ShardEvent::Join { worker: id });
            }
        }
        conn.phase = Phase::Attached { worker: id };
        self.timers
            .arm(conn.next_hb, idx, conn.gen, TimerKind::Heartbeat);
        Ok(())
    }

    /// Steady-state message dispatch — semantics identical to the threaded
    /// `server_read_loop`, including the pre-shard geometry validation.
    fn on_worker_msg(
        &mut self,
        conn: &mut Conn,
        idx: usize,
        worker: usize,
        msg: Msg,
        frame_bytes: u64,
    ) -> Result<(), String> {
        match msg {
            Msg::SubmitGrad {
                shard,
                seq: _,
                base_version,
                loss,
                grad,
            } => {
                let shard = shard as usize;
                if shard >= self.layout.shards() {
                    return Err(format!(
                        "submit to shard {shard} of {}",
                        self.layout.shards()
                    ));
                }
                // Reject payloads sized for a different shard geometry
                // before they reach a shard thread (`ShardGrad::view`'s
                // size checks are debug-only; a panicking shard thread
                // would take the whole server down).
                let expect = self.layout.range(shard).len();
                let local_len = match &grad {
                    ShardGrad::DenseLocal(g) => g.len(),
                    ShardGrad::QuantLocal(q) => q.data.len(),
                    ShardGrad::Sparse(s) => s.dim,
                    ShardGrad::SparseQuant(s) => s.dim,
                    ShardGrad::Dense(g) => g.len(),
                    ShardGrad::Quant(q) => q.data.len(),
                };
                if local_len != expect {
                    return Err(format!(
                        "worker {worker} sent a shard-{shard} payload sized {local_len}, \
                         expected {expect} (geometry mismatch)"
                    ));
                }
                self.counters
                    .grad_frame_bytes
                    .fetch_add(frame_bytes, Ordering::Relaxed);
                if shard == 0 {
                    self.counters.submissions.fetch_add(1, Ordering::Relaxed);
                }
                // Stamp the shard-queue entry time so `run_shard` can
                // close the Queue span; 0 (untraced) suppresses it.
                let enq_ns = self.trace.as_ref().map_or(0, |tr| tr.real_now());
                if self.grad_txs[shard]
                    .send(ShardEvent::Grad(ShardMsg {
                        worker,
                        base_version,
                        loss,
                        grad,
                        enq_ns,
                    }))
                    .is_err()
                {
                    return Err(String::new()); // shards gone: run is over
                }
            }
            Msg::SnapshotRequest { shard, version } => {
                let shard = shard as usize;
                if shard >= self.layout.shards() {
                    return Err(format!(
                        "snapshot request for shard {shard} of {}",
                        self.layout.shards()
                    ));
                }
                let snap = self.cells[shard].load();
                if snapshot_serves_full(&snap, self.net.snap_full_max) {
                    // Legacy small-f32 reply: frame straight out of the
                    // snapshot — no theta clone. Cannot overflow a length
                    // field (the slice fits one ≤64 MiB frame).
                    encode_snapshot_slice_into(
                        shard as u32,
                        snap.version,
                        snap.theta(),
                        &mut self.scratch,
                    )
                    .expect("full-slice reply within the frame limit");
                    self.queue_scratch(conn);
                } else {
                    // Oversized or half-precision: chunked delta stream,
                    // only the blocks newer than the worker's version.
                    for m in snapshot_response_msgs(
                        shard as u32,
                        &snap,
                        version,
                        self.net.snap_full_max,
                    ) {
                        self.queue(conn, &m);
                    }
                }
            }
            Msg::Heartbeat { .. } => {}
            Msg::Shutdown => return Err(String::new()), // clean client exit
            Msg::Leave { .. } => return Err(String::new()), // clean departure
            Msg::Hello { .. } => {} // duplicate hello: ignore
            Msg::StatusRequest => {
                // Read-only ops probe from an attached worker; the reply
                // is assembled from atomics, never the gradient plane.
                let json = self.status_doc();
                self.queue(conn, &Msg::Status { json });
            }
            Msg::Subscribe { interval_ms } => {
                // Attached workers may subscribe too; deltas interleave
                // with acks on the same outbound queue.
                self.subscribe(conn, idx, interval_ms);
            }
            other => {
                log_warn!("transport", "worker {worker} sent unexpected {other:?}");
            }
        }
        Ok(())
    }

    /// The status document (DESIGN.md §2.9), assembled from atomics.
    fn status_doc(&self) -> String {
        super::render_status(
            "reactor",
            &self.layout,
            self.slots.len(),
            self.counters.active_conns.load(Ordering::Relaxed),
            self.counters.ever_joined.load(Ordering::Relaxed),
            self.counters.grad_frame_bytes.load(Ordering::Relaxed),
            self.counters.submissions.load(Ordering::Relaxed),
            self.started.elapsed(),
            self.status.as_deref(),
            self.trace.as_deref(),
        )
    }

    /// Begin (or retime) a status subscription: push the first delta
    /// immediately, then one per interval from the timer wheel. The
    /// interval floor bounds how hard one follower can drive the loop.
    fn subscribe(&mut self, conn: &mut Conn, idx: usize, interval_ms: u32) {
        let interval = Duration::from_millis(u64::from(interval_ms.max(10)));
        let first = conn.sub.is_none();
        let sub = conn.sub.get_or_insert(Sub { interval, seq: 0 });
        sub.interval = interval;
        let seq = sub.seq;
        sub.seq += 1;
        let json = self.status_doc();
        self.queue(conn, &Msg::StatusDelta { seq, json });
        // Re-subscribing only retimes: the old timer keeps firing and
        // simply pushes at the (updated) cadence it reads off the Conn.
        if first {
            self.timers
                .arm(self.now + interval, idx, conn.gen, TimerKind::StatusPush);
        }
    }

    /// Encode `msg` and append it, framed, onto `conn`'s write queue.
    fn queue(&mut self, conn: &mut Conn, msg: &Msg) {
        if let Err(e) = msg.encode_into(&mut self.scratch) {
            // Server-built messages stay within the u32 length fields by
            // construction; drop rather than corrupt the stream if not.
            log_warn!("transport", "dropping unencodable {e}");
            return;
        }
        self.queue_scratch(conn);
    }

    /// Frame `self.scratch` (a message body) onto the write queue,
    /// coalescing into the tail buffer while it stays under the cap.
    fn queue_scratch(&mut self, conn: &mut Conn) {
        if conn.outq.back().map_or(true, |b| b.len() >= COALESCE_CAP) {
            let buf = self.pool.take();
            conn.outq.push_back(buf);
        }
        encode_frame_into(&self.scratch, conn.outq.back_mut().expect("queued buffer"));
        // Any outbound frame counts as traffic: push the idle heartbeat.
        conn.next_hb = self.now + self.net.hb_interval;
    }

    /// Move every pending shard reply into its connection's write queue.
    fn drain_replies(&mut self) {
        for idx in 0..self.conns.len() {
            let Some(mut conn) = self.conns[idx].take() else {
                continue;
            };
            if let Some(rx) = conn.reply_rx.take() {
                loop {
                    match rx.try_recv() {
                        Ok(reply) => {
                            let msg = match reply {
                                Reply::Updated { shard, version } => Msg::GradAck {
                                    shard: shard as u32,
                                    version,
                                    changed: true,
                                },
                                Reply::Unchanged { shard } => Msg::GradAck {
                                    shard: shard as u32,
                                    version: 0,
                                    changed: false,
                                },
                            };
                            self.queue(&mut conn, &msg);
                        }
                        Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                    }
                }
                conn.reply_rx = Some(rx);
            }
            self.conns[idx] = Some(conn);
        }
    }

    fn fire_timers(&mut self) {
        let now = self.now;
        while let Some(e) = self.timers.pop_due(now) {
            let stale = match self.conns.get(e.conn).and_then(|c| c.as_ref()) {
                Some(c) => c.gen != e.gen,
                None => true,
            };
            if stale {
                continue;
            }
            let mut conn = self.conns[e.conn].take().expect("checked above");
            let mut close: Option<String> = None;
            match e.kind {
                TimerKind::Heartbeat => {
                    if matches!(conn.phase, Phase::Attached { .. }) && now >= conn.next_hb {
                        conn.hb_seq += 1;
                        let hb = Msg::Heartbeat { seq: conn.hb_seq };
                        self.queue(&mut conn, &hb);
                    }
                    let next = conn.next_hb.max(now + Duration::from_millis(1));
                    self.timers.arm(next, e.conn, conn.gen, TimerKind::Heartbeat);
                }
                TimerKind::Liveness => {
                    if now.saturating_duration_since(conn.last_frame) > self.net.hb_timeout {
                        close = Some(match conn.phase {
                            Phase::Attached { worker } => format!(
                                "worker {worker} silent past the heartbeat timeout (half-open)"
                            ),
                            Phase::Handshake => {
                                "timed out waiting for a handshake message".to_string()
                            }
                            // A refused peer that never read its refusal:
                            // drain window over, close quietly.
                            Phase::Draining => String::new(),
                        });
                    } else {
                        let next = conn.last_frame + self.net.hb_timeout
                            + Duration::from_millis(1);
                        self.timers.arm(next, e.conn, conn.gen, TimerKind::Liveness);
                    }
                }
                TimerKind::StatusPush => {
                    if let Some(sub) = &mut conn.sub {
                        let seq = sub.seq;
                        sub.seq += 1;
                        let interval = sub.interval;
                        let json = self.status_doc();
                        self.queue(&mut conn, &Msg::StatusDelta { seq, json });
                        self.timers
                            .arm(now + interval, e.conn, conn.gen, TimerKind::StatusPush);
                    }
                }
            }
            match close {
                None => self.conns[e.conn] = Some(conn),
                Some(reason) => self.teardown(conn, e.conn, &reason),
            }
        }
    }

    /// Try to flush every connection with queued output; close drained
    /// `Draining` connections.
    fn flush_pass(&mut self) {
        for idx in 0..self.conns.len() {
            let Some(mut conn) = self.conns[idx].take() else {
                continue;
            };
            let mut close: Option<String> = None;
            if !conn.outq.is_empty() {
                if let Err(reason) = flush_conn(&mut self.pool, &mut conn) {
                    close = Some(reason);
                }
            }
            if close.is_none()
                && matches!(conn.phase, Phase::Draining)
                && conn.outq.is_empty()
            {
                close = Some(String::new()); // refusal delivered
            }
            match close {
                None => self.conns[idx] = Some(conn),
                Some(reason) => self.teardown(conn, idx, &reason),
            }
        }
    }

    /// Close one connection: return the slot (elastic `Leave` first, after
    /// every gradient it delivered — same channel FIFO ordering argument
    /// as the threaded teardown), recycle its buffers, free the slab entry.
    fn teardown(&mut self, mut conn: Conn, idx: usize, reason: &str) {
        if !reason.is_empty() {
            log_warn!(
                "transport",
                "connection from {} ended: {reason}",
                conn.peer
            );
        }
        if let Phase::Attached { worker } = conn.phase {
            // A for-cause close of an attached worker is an eviction from
            // the frontend's perspective (the shard records the Leave).
            if !reason.is_empty() {
                if let Some(tr) = &self.trace {
                    tr.instant(Stage::Evict, worker as u32, 0, tr.real_now(), 0, 0);
                }
            }
            // Suppressed once the run is stopping: end-of-run disconnects
            // are not membership churn.
            if self.elastic && !self.stop.load(Ordering::Relaxed) {
                for tx in &self.grad_txs {
                    let _ = tx.send(ShardEvent::Leave { worker });
                }
            }
            let slot = &mut self.slots[worker];
            slot.reply_rx = conn.reply_rx.take();
            slot.attached = false;
            slot.vacancies += 1;
            self.counters.active_conns.fetch_sub(1, Ordering::Relaxed);
        }
        while let Some(buf) = conn.outq.pop_front() {
            self.pool.put(buf);
        }
        self.conns[idx] = None;
        self.free.push(idx);
        // conn.stream drops here: socket closed. Timers for this (idx,
        // gen) pair die lazily on their generation check.
    }

    /// Stop path: queue `Shutdown` to every attached worker, flush with a
    /// bounded grace, then tear everything down (Leave suppressed — the
    /// stop flag is already set).
    fn shutdown_conns(&mut self) {
        self.now = Instant::now();
        for idx in 0..self.conns.len() {
            let Some(mut conn) = self.conns[idx].take() else {
                continue;
            };
            if matches!(conn.phase, Phase::Attached { .. }) {
                self.queue(&mut conn, &Msg::Shutdown);
            }
            self.conns[idx] = Some(conn);
        }
        let deadline = Instant::now() + Duration::from_millis(500);
        loop {
            let pending = self.conns.iter().flatten().any(|c| !c.outq.is_empty());
            if !pending || Instant::now() >= deadline {
                break;
            }
            self.build_pollfds();
            poll_fds(&mut self.pollfds, Duration::from_millis(10));
            self.now = Instant::now();
            self.flush_pass();
        }
        for idx in 0..self.conns.len() {
            if let Some(conn) = self.conns[idx].take() {
                self.teardown(conn, idx, "");
            }
        }
    }
}

/// Write as much of `conn`'s queue as the socket accepts, up to
/// [`MAX_IOVECS`] buffers per `write_vectored` call. Fully written buffers
/// recycle into the pool. `Err` means the connection is gone.
fn flush_conn(pool: &mut BufPool, conn: &mut Conn) -> Result<(), String> {
    let Conn {
        ref mut stream,
        ref mut outq,
        ref mut front_written,
        ..
    } = *conn;
    loop {
        // Recycle fully-written front buffers before building the iovec,
        // so every slice handed to the kernel is non-empty.
        while outq.front().map_or(false, |b| b.len() == *front_written) {
            let done = outq.pop_front().expect("checked front");
            pool.put(done);
            *front_written = 0;
        }
        if outq.is_empty() {
            return Ok(());
        }
        let wrote = {
            let mut iov: [IoSlice; MAX_IOVECS] = [IoSlice::new(&[]); MAX_IOVECS];
            let mut cnt = 0;
            for (i, buf) in outq.iter().enumerate() {
                if cnt == MAX_IOVECS {
                    break;
                }
                iov[cnt] = IoSlice::new(if i == 0 { &buf[*front_written..] } else { &buf[..] });
                cnt += 1;
            }
            stream.write_vectored(&iov[..cnt])
        };
        match wrote {
            Ok(0) => return Err("write returned 0 (peer gone)".into()),
            Ok(mut n) => {
                while n > 0 {
                    let front_len = outq
                        .front()
                        .map_or(0, |b| b.len() - *front_written);
                    if n >= front_len {
                        n -= front_len;
                        let done = outq.pop_front().expect("non-empty front");
                        pool.put(done);
                        *front_written = 0;
                    } else {
                        *front_written += n;
                        n = 0;
                    }
                }
                // Loop: try again until WouldBlock or drained.
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                return Ok(())
            }
            Err(e) => return Err(format!("write error: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::compress::SparseGrad;
    use crate::transport::msg::encode_submit_into;
    use crate::transport::tcp::read_msg_blocking;
    use crate::transport::{TcpTransport, Transport, TransportError};
    use std::sync::mpsc;

    fn quick_net() -> NetOptions {
        NetOptions {
            hb_interval: Duration::from_millis(50),
            hb_timeout: Duration::from_millis(400),
            connect_timeout: Duration::from_secs(3),
            reconnect_attempts: 1,
            ..NetOptions::default()
        }
    }

    /// Minimal in-test server on the reactor: 2 shards over dim 4, cells
    /// seeded [1,2]/[3,4] — the same geometry as the threaded frontend's
    /// test server, so the scenario suites stay comparable line for line.
    fn spawn_reactor(
        workers: usize,
        elastic: bool,
    ) -> (
        TcpFrontend,
        String,
        Vec<Receiver<ShardEvent>>,
        Vec<Sender<Reply>>,
        Arc<AtomicBool>,
    ) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = format!("{}", listener.local_addr().unwrap());
        let layout = ShardLayout::new(4, 2);
        let mut grad_txs = Vec::new();
        let mut grad_rxs = Vec::new();
        for _ in 0..2 {
            let (tx, rx) = mpsc::channel();
            grad_txs.push(tx);
            grad_rxs.push(rx);
        }
        let mut reply_txs = Vec::new();
        let mut reply_rxs = Vec::new();
        for _ in 0..workers {
            let (tx, rx) = mpsc::channel();
            reply_txs.push(tx);
            reply_rxs.push(rx);
        }
        let cells = vec![
            Arc::new(SnapshotCell::new(vec![1.0, 2.0])),
            Arc::new(SnapshotCell::new(vec![3.0, 4.0])),
        ];
        let stop = Arc::new(AtomicBool::new(false));
        let frontend = TcpFrontend::start(
            listener,
            layout,
            grad_txs,
            cells,
            reply_rxs,
            vec![false; workers],
            Arc::clone(&stop),
            quick_net(),
            elastic,
            Some(Arc::new(StatusBoard::new(2))),
            None,
        )
        .unwrap();
        (frontend, addr, grad_rxs, reply_txs, stop)
    }

    fn recv_grad(rx: &Receiver<ShardEvent>, timeout: Duration) -> ShardMsg {
        match rx.recv_timeout(timeout).expect("shard event") {
            ShardEvent::Grad(m) => m,
            ShardEvent::Join { .. } => panic!("expected a gradient, got a join"),
            ShardEvent::Leave { .. } => panic!("expected a gradient, got a leave"),
        }
    }

    fn recv_membership(rx: &Receiver<ShardEvent>, timeout: Duration) -> (bool, usize) {
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(remaining).expect("membership event") {
                ShardEvent::Join { worker } => return (true, worker),
                ShardEvent::Leave { worker } => return (false, worker),
                ShardEvent::Grad(_) => {}
            }
        }
    }

    fn raw_attach(addr: &str, worker: u32) -> (TcpStream, Msg) {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut reader = FrameReader::new();
        let mut payload = Vec::new();
        let mut msg_buf = Vec::new();
        let mut frame_buf = Vec::new();
        Msg::Hello {
            worker,
            shards: 0,
            wire: "dense".into(),
        }
        .encode_into(&mut msg_buf).unwrap();
        encode_frame_into(&msg_buf, &mut frame_buf);
        s.write_all(&frame_buf).unwrap();
        let deadline = Instant::now() + Duration::from_secs(3);
        let reply = read_msg_blocking(&mut s, &mut reader, &mut payload, deadline).unwrap();
        (s, reply)
    }

    fn connect_when_slot_frees(addr: &str, net: NetOptions) -> TcpTransport {
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match TcpTransport::connect(addr, "dense", net.clone()) {
                Ok(t) => return t,
                Err(e) => {
                    assert!(Instant::now() < deadline, "slot never freed: {e:#}");
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        }
    }

    #[test]
    fn reactor_attach_submit_ack_refresh_roundtrip() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, grad_rxs, reply_txs, _stop) = spawn_reactor(2, false);
        let mut t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        let info = t.attach_info();
        assert_eq!(info.worker, 0);
        assert_eq!(info.workers, 2);
        assert_eq!(info.shards, 2);
        assert_eq!(info.dim, 4);

        let mut buf = [0.0f32; 2];
        let v = t.refresh(1, &mut buf).unwrap();
        assert_eq!(v, 0);
        assert_eq!(buf, [3.0, 4.0]);

        t.submit(
            1,
            ShardMsg {
                worker: 0,
                base_version: 3,
                loss: 0.5,
                grad: ShardGrad::Dense(Arc::new(vec![1.0, 2.0, 3.0, 4.0])),
                enq_ns: 0,
            },
        )
        .unwrap();
        let msg = recv_grad(&grad_rxs[1], Duration::from_secs(2));
        assert_eq!(msg.worker, 0);
        assert_eq!(msg.base_version, 3);
        let mut got = vec![0.0f32; 2];
        msg.grad.view(2..4).add_to(&mut got);
        assert_eq!(got, vec![3.0, 4.0]);

        reply_txs[0]
            .send(Reply::Updated { shard: 1, version: 9 })
            .unwrap();
        let r = t.recv_reply(Duration::from_secs(2)).unwrap();
        assert_eq!(r, Reply::Updated { shard: 1, version: 9 });
        // Submission byte accounting is identical to the threaded frontend
        // (the wire-bytes invariant, measured server-side).
        let expected = (FRAME_OVERHEAD
            + crate::transport::msg::SUBMIT_HEADER_BYTES
            + crate::transport::msg::GRAD_DENSE_HEADER_BYTES
            + 8) as u64;
        let stats = frontend.stats();
        assert_eq!(stats.grad_frame_bytes, expected);
        assert_eq!(stats.submissions, 0, "shard-1 submit is not a new submission");
        drop(t);
        frontend.shutdown();
    }

    #[test]
    fn reactor_oversized_slice_refreshes_via_chunked_delta() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        // Same acceptance as the threaded frontend's test: a shard slice
        // above the 64 MiB frame cap must stream as chunked SnapshotDelta
        // frames through the reactor's non-blocking write queue and
        // reconstruct bitwise.
        let dim = crate::transport::frame::MAX_PAYLOAD / 4 + 1;
        let theta: Vec<f32> = (0..dim as u32)
            .map(|i| f32::from_bits(i.wrapping_mul(0x9E37_79B9) >> 1))
            .collect();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = format!("{}", listener.local_addr().unwrap());
        let (grad_tx, _grad_rx) = mpsc::channel();
        let (_reply_tx, reply_rx) = mpsc::channel();
        let cells = vec![Arc::new(SnapshotCell::new(theta.clone()))];
        let stop = Arc::new(AtomicBool::new(false));
        let net = NetOptions {
            hb_timeout: Duration::from_secs(60),
            ..quick_net()
        };
        let frontend = TcpFrontend::start(
            listener,
            ShardLayout::new(dim, 1),
            vec![grad_tx],
            cells,
            vec![reply_rx],
            vec![false],
            Arc::clone(&stop),
            net.clone(),
            false,
            None,
            None,
        )
        .unwrap();
        let mut t = TcpTransport::connect(&addr, "dense", net).unwrap();
        let mut out = vec![0.0f32; dim];
        let v = t.refresh(0, &mut out).unwrap();
        assert_eq!(v, 0);
        for (i, (a, b)) in out.iter().zip(&theta).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "elem {i}");
        }
        drop(t);
        frontend.shutdown();
    }

    #[test]
    fn reactor_second_worker_attaches_and_extra_is_refused() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, _grad_rxs, _reply_txs, _stop) = spawn_reactor(2, false);
        let t0 = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        let t1 = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(t0.attach_info().worker, 0);
        assert_eq!(t1.attach_info().worker, 1);
        assert_eq!(frontend.active_conns(), 2);
        assert_eq!(frontend.ever_joined(), 2);
        let err = TcpTransport::connect(&addr, "dense", quick_net());
        assert!(err.is_err(), "third attach must be refused");
        drop(t0);
        drop(t1);
        frontend.shutdown();
    }

    #[test]
    fn reactor_geometry_mismatch_drops_the_connection_not_the_server() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, grad_rxs, _reply_txs, _stop) = spawn_reactor(2, false);
        let (mut s, welcome) = raw_attach(&addr, WORKER_UNASSIGNED);
        assert!(matches!(welcome, Msg::Welcome { .. }));
        let evil = ShardGrad::Sparse(Arc::new(SparseGrad {
            dim: 1000,
            idx: vec![999],
            val: vec![1.0],
        }));
        let mut msg_buf = Vec::new();
        let mut frame_buf = Vec::new();
        encode_submit_into(0, 0, 0, 0.0, &evil, 0..1000, &mut msg_buf).unwrap();
        encode_frame_into(&msg_buf, &mut frame_buf);
        s.write_all(&frame_buf).unwrap();
        assert!(grad_rxs[0].recv_timeout(Duration::from_millis(300)).is_err());
        // The reactor survives: a well-formed worker still flows.
        let mut t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        t.submit(
            0,
            ShardMsg {
                worker: 0,
                base_version: 0,
                loss: 0.0,
                grad: ShardGrad::Dense(Arc::new(vec![1.0, 2.0, 3.0, 4.0])),
                enq_ns: 0,
            },
        )
        .unwrap();
        let msg = recv_grad(&grad_rxs[0], Duration::from_secs(2));
        let mut got = vec![0.0f32; 2];
        msg.grad.view(0..2).add_to(&mut got);
        assert_eq!(got, vec![1.0, 2.0]);
        drop(t);
        drop(s);
        frontend.shutdown();
    }

    #[test]
    fn reactor_coalesces_an_ack_burst_and_delivers_all_of_them() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        // Many replies queued between two reactor iterations must all
        // arrive, in order — they leave coalesced into few vectored
        // writes, which this asserts indirectly via count + ordering.
        let (frontend, addr, _grad_rxs, reply_txs, _stop) = spawn_reactor(1, false);
        let mut t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        const BURST: u64 = 64;
        for version in 1..=BURST {
            reply_txs[0]
                .send(Reply::Updated { shard: 0, version })
                .unwrap();
        }
        for version in 1..=BURST {
            let r = t.recv_reply(Duration::from_secs(2)).unwrap();
            assert_eq!(r, Reply::Updated { shard: 0, version });
        }
        drop(t);
        frontend.shutdown();
    }

    #[test]
    fn reactor_elastic_attach_and_clean_leave_announce_membership() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, grad_rxs, _reply_txs, _stop) = spawn_reactor(2, true);
        let t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(t.attach_info().worker, 0);
        for rx in &grad_rxs {
            assert_eq!(recv_membership(rx, Duration::from_secs(2)), (true, 0));
        }
        drop(t); // clean Leave frame
        for rx in &grad_rxs {
            assert_eq!(recv_membership(rx, Duration::from_secs(2)), (false, 0));
        }
        let t2 = connect_when_slot_frees(&addr, quick_net());
        assert_eq!(t2.attach_info().worker, 0);
        for rx in &grad_rxs {
            assert_eq!(recv_membership(rx, Duration::from_secs(2)), (true, 0));
        }
        drop(t2);
        frontend.shutdown();
    }

    #[test]
    fn reactor_evicts_half_open_worker_after_heartbeat_timeout() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, grad_rxs, _reply_txs, _stop) = spawn_reactor(1, true);
        let (mut s, reply) = raw_attach(&addr, WORKER_UNASSIGNED);
        assert!(matches!(reply, Msg::Welcome { worker: 0, .. }));
        assert_eq!(
            recv_membership(&grad_rxs[0], Duration::from_secs(2)),
            (true, 0)
        );
        let mut msg_buf = Vec::new();
        let mut frame_buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.5,
            &ShardGrad::Dense(Arc::new(vec![1.0, 2.0, 3.0, 4.0])),
            0..2,
            &mut msg_buf,
        )
        .unwrap();
        encode_frame_into(&msg_buf, &mut frame_buf);
        s.write_all(&frame_buf).unwrap();
        let grad = recv_grad(&grad_rxs[0], Duration::from_secs(2));
        assert_eq!(grad.worker, 0);
        // No heartbeats: the liveness timer evicts after ~400 ms.
        let start = Instant::now();
        let (join, worker) = recv_membership(&grad_rxs[0], Duration::from_secs(5));
        assert!(!join, "expected an eviction Leave, got a Join");
        assert_eq!(worker, 0);
        assert!(
            start.elapsed() >= Duration::from_millis(200),
            "evicted before the heartbeat timeout could plausibly elapse"
        );
        let t = connect_when_slot_frees(&addr, quick_net());
        assert_eq!(t.attach_info().worker, 0);
        drop(t);
        drop(s);
        frontend.shutdown();
    }

    #[test]
    fn reactor_zombie_reattach_to_reassigned_slot_is_evicted() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, _grad_rxs, _reply_txs, _stop) = spawn_reactor(1, true);
        let original = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(original.attach_info().worker, 0);
        drop(original);
        let replacement = connect_when_slot_frees(&addr, quick_net());
        assert_eq!(replacement.attach_info().worker, 0);
        let (_s, reply) = raw_attach(&addr, 0);
        assert!(
            matches!(reply, Msg::Evict { worker: 0 }),
            "expected Evict, got {reply:?}"
        );
        drop(replacement);
        frontend.shutdown();
    }

    #[test]
    fn reactor_first_blip_named_redial_stays_retryable() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, _grad_rxs, _reply_txs, _stop) = spawn_reactor(1, true);
        let holder = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(holder.attach_info().worker, 0);
        let (_s, reply) = raw_attach(&addr, 0);
        assert!(
            matches!(reply, Msg::Shutdown),
            "expected a retryable Shutdown, got {reply:?}"
        );
        drop(holder);
        frontend.shutdown();
    }

    #[test]
    fn reactor_static_refusal_is_retryable_and_silent_on_membership() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, grad_rxs, _reply_txs, _stop) = spawn_reactor(1, false);
        let holder = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(holder.attach_info().worker, 0);
        let (_s, reply) = raw_attach(&addr, 0);
        assert!(matches!(reply, Msg::Shutdown), "expected Shutdown, got {reply:?}");
        assert!(
            grad_rxs[0].try_recv().is_err(),
            "static frontend must not emit membership events"
        );
        drop(holder);
        frontend.shutdown();
    }

    #[test]
    fn reactor_reconnect_reattaches_the_freed_slot() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, grad_rxs, _reply_txs, _stop) = spawn_reactor(1, false);
        let mut net = quick_net();
        net.hb_timeout = Duration::from_millis(300);
        net.reconnect_attempts = 10;
        let mut t = TcpTransport::connect(&addr, "dense", net).unwrap();
        assert_eq!(t.attach_info().worker, 0);
        t.kill_socket_for_test();
        let start = Instant::now();
        let mut reconnected = false;
        while start.elapsed() < Duration::from_secs(5) {
            match t.recv_reply(Duration::from_millis(50)) {
                Err(TransportError::Reconnected) => {
                    reconnected = true;
                    break;
                }
                Err(TransportError::Timeout) => {}
                Err(TransportError::Closed(why)) => panic!("gave up: {why}"),
                Ok(r) => panic!("unexpected reply {r:?}"),
            }
        }
        assert!(reconnected, "transport never reconnected");
        assert_eq!(t.attach_info().worker, 0, "slot changed across reconnect");
        t.submit(
            0,
            ShardMsg {
                worker: 0,
                base_version: 0,
                loss: 0.0,
                grad: ShardGrad::Dense(Arc::new(vec![1.0, 2.0, 3.0, 4.0])),
                enq_ns: 0,
            },
        )
        .unwrap();
        let msg = recv_grad(&grad_rxs[0], Duration::from_secs(2));
        assert_eq!(msg.worker, 0);
        drop(t);
        frontend.shutdown();
    }

    #[test]
    fn reactor_status_endpoint_answers_without_taking_a_slot() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, _grad_rxs, _reply_txs, _stop) = spawn_reactor(1, false);
        // A pre-attach probe answers from the handshake phase...
        let doc = crate::transport::tcp::query_status(&addr, &quick_net()).unwrap();
        let json = crate::util::json::parse(&doc).expect("status must parse");
        assert_eq!(json.get("frontend").and_then(|j| j.as_str()), Some("reactor"));
        let workers = json.get("workers").expect("workers object");
        assert_eq!(workers.get("slots").and_then(|j| j.as_f64()), Some(1.0));
        assert_eq!(workers.get("active").and_then(|j| j.as_f64()), Some(0.0));
        // ...and the lazy reader agrees with the full parse.
        assert_eq!(
            crate::util::json::scan_path(&doc, "workers.active").unwrap(),
            Some(crate::util::json::Json::Num(0.0)),
        );
        // ...without consuming the single worker slot:
        let t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(t.attach_info().worker, 0);
        // A mid-run probe sees the attached worker and per-shard entries.
        let doc = crate::transport::tcp::query_status(&addr, &quick_net()).unwrap();
        assert_eq!(
            crate::util::json::scan_path(&doc, "workers.active").unwrap(),
            Some(crate::util::json::Json::Num(1.0)),
        );
        assert_eq!(
            crate::util::json::scan_path(&doc, "shards[1].shard").unwrap(),
            Some(crate::util::json::Json::Num(1.0)),
        );
        // Status traffic is ops-plane only: gradient counters untouched.
        let stats = frontend.stats();
        assert_eq!(stats.grad_frame_bytes, 0);
        assert_eq!(stats.submissions, 0);
        drop(t);
        frontend.shutdown();
    }

    #[test]
    fn reactor_subscription_pushes_incrementing_deltas_without_a_slot() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, _grad_rxs, _reply_txs, _stop) = spawn_reactor(1, false);
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut reader = FrameReader::new();
        let mut payload = Vec::new();
        let mut msg_buf = Vec::new();
        let mut frame_buf = Vec::new();
        Msg::Subscribe { interval_ms: 20 }.encode_into(&mut msg_buf).unwrap();
        encode_frame_into(&msg_buf, &mut frame_buf);
        s.write_all(&frame_buf).unwrap();
        let deadline = Instant::now() + Duration::from_secs(3);
        for expect_seq in 0..3u64 {
            let msg = read_msg_blocking(&mut s, &mut reader, &mut payload, deadline).unwrap();
            let Msg::StatusDelta { seq, json } = msg else {
                panic!("expected StatusDelta, got {msg:?}");
            };
            assert_eq!(seq, expect_seq);
            let doc = crate::util::json::parse(&json).expect("delta must parse");
            assert_eq!(doc.get("frontend").and_then(|j| j.as_str()), Some("reactor"));
        }
        // The follower never consumed the worker slot.
        let t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        assert_eq!(t.attach_info().worker, 0);
        drop(t);
        drop(s);
        frontend.shutdown();
    }

    #[test]
    fn reactor_shutdown_notifies_connected_workers() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let (frontend, addr, _grad_rxs, _reply_txs, _stop) = spawn_reactor(1, false);
        let mut t = TcpTransport::connect(&addr, "dense", quick_net()).unwrap();
        frontend.shutdown();
        // The client observes the Shutdown as a terminal Closed (not an
        // endless reconnect): the server told it the run is over.
        let start = Instant::now();
        let mut closed = false;
        while start.elapsed() < Duration::from_secs(5) {
            match t.recv_reply(Duration::from_millis(50)) {
                Err(TransportError::Closed(_)) => {
                    closed = true;
                    break;
                }
                Err(_) => {}
                Ok(r) => panic!("unexpected reply {r:?}"),
            }
        }
        assert!(closed, "client never observed the server Shutdown");
    }
}
