//! Connections-vs-throughput measurement harness for the serving
//! frontends (DESIGN.md §2.8, `BENCH_transport.json`'s
//! `connections_vs_throughput` section).
//!
//! One call spins up a frontend of the requested kind over a minimal
//! single-shard parameter server stub (an echo thread acking every
//! submission with `Reply::Updated`), drives it with `conns` raw blocking
//! clients each keeping `window` submissions in flight, and reports
//! aggregate acks/sec plus the p99 submit→ack latency. The clients speak
//! the production wire protocol byte-for-byte (Hello → Welcome →
//! pipelined SubmitGrad/GradAck), so the measurement exercises the real
//! framing, coalescing and scheduling paths — only the SGD math is
//! stubbed out.
//!
//! Used by `cargo bench`-style runs in `benches/bench_hotpath.rs` and by
//! the tier-1 baseline filler in `tests/bench_baselines.rs`; it lives in
//! the library so both see one implementation.

use super::frame::{encode_frame_into, FrameError, FrameReader};
use super::msg::{encode_submit_into, Msg, WORKER_UNASSIGNED};
use super::{Frontend, FrontendKind, NetOptions};
use crate::coordinator::compress::ShardGrad;
use crate::coordinator::params::SnapshotCell;
use crate::coordinator::server::{Reply, ShardEvent};
use crate::coordinator::shard::ShardLayout;
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// One row of the scaling curve.
#[derive(Clone, Copy, Debug)]
pub struct ConnBenchResult {
    /// Concurrent client connections driven.
    pub conns: usize,
    /// Aggregate acknowledged submissions per second across all clients.
    pub ops_per_sec: f64,
    /// 99th-percentile submit→ack round-trip, microseconds.
    pub p99_ack_latency_us: f64,
    /// Total acks observed (sanity: > 0 or the row is meaningless).
    pub acks: u64,
}

/// Measure one (frontend, connection-count) point: `conns` clients, each
/// pipelining `window` dense submissions of `dim` f32s over a single
/// shard, for roughly `duration` of wall clock. Returns aggregate
/// throughput and tail latency; errors are I/O-environmental (bind/dial
/// failures), not protocol outcomes.
pub fn measure_conn_throughput(
    kind: FrontendKind,
    conns: usize,
    window: usize,
    dim: usize,
    duration: Duration,
) -> std::io::Result<ConnBenchResult> {
    assert!(conns >= 1 && window >= 1 && dim >= 1);
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let layout = ShardLayout::new(dim, 1);
    let (grad_tx, grad_rx) = mpsc::channel::<ShardEvent>();
    let mut reply_txs = Vec::with_capacity(conns);
    let mut reply_rxs = Vec::with_capacity(conns);
    for _ in 0..conns {
        let (tx, rx) = mpsc::channel::<Reply>();
        reply_txs.push(tx);
        reply_rxs.push(rx);
    }
    let cells = vec![Arc::new(SnapshotCell::new(vec![0.0f32; dim]))];
    let stop = Arc::new(AtomicBool::new(false));
    // Heartbeats stay out of the measurement window: intervals far longer
    // than any plausible `duration`.
    let net = NetOptions {
        hb_interval: Duration::from_secs(60),
        hb_timeout: Duration::from_secs(300),
        connect_timeout: Duration::from_secs(5),
        reconnect_attempts: 0,
        ..NetOptions::default()
    };
    let frontend = Frontend::start(
        kind,
        listener,
        layout,
        vec![grad_tx],
        cells,
        reply_rxs,
        vec![false; conns],
        Arc::clone(&stop),
        net,
        false,
        None,
        None,
    )?;
    let notify = frontend.reply_notifier();

    // Echo "shard server": ack every submission immediately. This is the
    // stub that isolates transport cost — the real `run_shard` would add
    // aggregation time identically under both frontends.
    let echo_stop = Arc::clone(&stop);
    let echo = std::thread::Builder::new()
        .name("loadgen-echo".into())
        .spawn(move || {
            let mut version = 0u64;
            loop {
                match grad_rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(ShardEvent::Grad(m)) => {
                        version += 1;
                        let _ = reply_txs[m.worker].send(Reply::Updated { shard: 0, version });
                        if let Some(n) = &notify {
                            n(m.worker);
                        }
                    }
                    Ok(_) => {} // Join/Leave: membership noise, not measured
                    Err(RecvTimeoutError::Timeout) => {
                        if echo_stop.load(Ordering::Relaxed) {
                            break;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        })
        .expect("spawn loadgen echo thread");

    // Clients: raw blocking sockets, `window` submissions in flight each.
    let barrier = Arc::new(Barrier::new(conns));
    let mut handles = Vec::with_capacity(conns);
    for _ in 0..conns {
        let barrier = Arc::clone(&barrier);
        handles.push(
            std::thread::Builder::new()
                .name("loadgen-client".into())
                .spawn(move || client_run(addr, window, dim, duration, &barrier))
                .expect("spawn loadgen client thread"),
        );
    }

    let mut total_acks = 0u64;
    let mut max_elapsed = Duration::ZERO;
    let mut latencies: Vec<Duration> = Vec::new();
    for h in handles {
        let stats = h
            .join()
            .expect("loadgen client panicked")
            .map_err(|e| other_err(format!("loadgen client: {e}")))?;
        total_acks += stats.acks;
        max_elapsed = max_elapsed.max(stats.elapsed);
        latencies.extend(stats.latencies);
    }
    stop.store(true, Ordering::Relaxed);
    frontend.shutdown();
    echo.join().expect("loadgen echo panicked");

    latencies.sort_unstable();
    let p99 = if latencies.is_empty() {
        0.0
    } else {
        let idx = ((latencies.len() as f64 * 0.99).ceil() as usize)
            .saturating_sub(1)
            .min(latencies.len() - 1);
        latencies[idx].as_secs_f64() * 1e6
    };
    let ops = if max_elapsed.is_zero() {
        0.0
    } else {
        total_acks as f64 / max_elapsed.as_secs_f64()
    };
    Ok(ConnBenchResult {
        conns,
        ops_per_sec: ops,
        p99_ack_latency_us: p99,
        acks: total_acks,
    })
}

struct ClientStats {
    acks: u64,
    elapsed: Duration,
    latencies: Vec<Duration>,
}

/// One client: attach, keep `window` submissions in flight for
/// `duration`, drain the tail, leave. Returns the acks it saw and the
/// per-submission round-trips.
fn client_run(
    addr: std::net::SocketAddr,
    window: usize,
    dim: usize,
    duration: Duration,
    barrier: &Barrier,
) -> std::io::Result<ClientStats> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let mut reader = FrameReader::new();
    let mut msg_buf = Vec::new();
    let mut frame_buf = Vec::new();

    // Attach exactly as TcpTransport does.
    Msg::Hello {
        worker: WORKER_UNASSIGNED,
        shards: 0,
        wire: "dense".to_string(),
    }
    .encode_into(&mut msg_buf)
    .map_err(|e| other_err(format!("loadgen encode: {e}")))?;
    frame_buf.clear();
    encode_frame_into(&msg_buf, &mut frame_buf);
    stream.write_all(&frame_buf)?;
    loop {
        match read_one(&mut stream, &mut reader)? {
            Msg::Welcome { .. } => break,
            Msg::Shutdown | Msg::Evict { .. } => {
                return Err(other_err("loadgen attach refused".to_string()));
            }
            _ => {}
        }
    }

    let grad = ShardGrad::Dense(Arc::new(vec![0.25f32; dim]));
    let mut seq = 0u64;
    let mut submit = |stream: &mut TcpStream,
                      msg_buf: &mut Vec<u8>,
                      frame_buf: &mut Vec<u8>|
     -> std::io::Result<Instant> {
        seq += 1;
        encode_submit_into(0, seq, 0, 0.0, &grad, 0..dim, msg_buf)
            .map_err(|e| other_err(format!("loadgen encode: {e}")))?;
        frame_buf.clear();
        encode_frame_into(msg_buf, frame_buf);
        let at = Instant::now();
        stream.write_all(frame_buf)?;
        Ok(at)
    };

    barrier.wait();
    let start = Instant::now();
    let mut inflight: VecDeque<Instant> = VecDeque::with_capacity(window);
    for _ in 0..window {
        inflight.push_back(submit(&mut stream, &mut msg_buf, &mut frame_buf)?);
    }
    let mut acks = 0u64;
    let mut latencies = Vec::new();
    let mut sending = true;
    while !inflight.is_empty() {
        match read_one(&mut stream, &mut reader)? {
            Msg::GradAck { .. } => {
                let sent = inflight.pop_front().expect("ack without a submission");
                latencies.push(sent.elapsed());
                acks += 1;
                if sending && start.elapsed() >= duration {
                    sending = false;
                    // Tail drain: bounded read patience from here on.
                    stream.set_read_timeout(Some(Duration::from_secs(2))).ok();
                }
                if sending {
                    inflight.push_back(submit(&mut stream, &mut msg_buf, &mut frame_buf)?);
                }
            }
            Msg::Shutdown => break, // run torn down under us: keep what we have
            _ => {}                 // heartbeats, snapshot slices: not measured
        }
    }
    let elapsed = start.elapsed();
    // A clean goodbye lets the frontend free the slot without logging.
    let _ = Msg::Shutdown.encode_into(&mut msg_buf);
    frame_buf.clear();
    encode_frame_into(&msg_buf, &mut frame_buf);
    let _ = stream.write_all(&frame_buf);
    Ok(ClientStats {
        acks,
        elapsed,
        latencies,
    })
}

/// Blocking read of the next whole message on `stream`.
fn read_one(stream: &mut TcpStream, reader: &mut FrameReader) -> std::io::Result<Msg> {
    let mut chunk = [0u8; 4096];
    let mut payload = Vec::new();
    loop {
        if reader.next_frame(&mut payload).map_err(frame_err_to_io)? {
            return Msg::decode(&payload).map_err(|e| other_err(format!("loadgen decode: {e}")));
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed mid-measurement",
            ));
        }
        reader.feed(&chunk[..n]);
    }
}

fn frame_err_to_io(e: FrameError) -> std::io::Error {
    other_err(format!("loadgen frame: {e}"))
}

fn other_err(why: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::Other, why)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke only — real numbers come from the bench harness. Both
    /// frontends must complete a short window-pipelined run and ack
    /// every in-flight submission.
    #[test]
    fn loadgen_measures_both_frontends() {
        for kind in [FrontendKind::Reactor, FrontendKind::Threaded] {
            let r = measure_conn_throughput(kind, 2, 4, 16, Duration::from_millis(60))
                .expect("loadgen run");
            assert_eq!(r.conns, 2);
            assert!(r.acks >= 8, "{kind:?}: too few acks: {}", r.acks);
            assert!(r.ops_per_sec > 0.0);
            assert!(r.p99_ack_latency_us > 0.0);
        }
    }
}
