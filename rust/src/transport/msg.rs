//! The control-plane message set and its binary encoding.
//!
//! One [`Msg`] travels per frame ([`super::frame`]). All integers are
//! little-endian; floats are IEEE-754 bit patterns (NaN-safe roundtrips).
//! Gradient payloads are encoded **shard-local**: a remote worker sends each
//! shard only its slice of the submission, so full-dimension formats (dense,
//! int8) are cut down to the shard's range at encode time and decode into
//! the shard-local [`ShardGrad::DenseLocal`] / [`ShardGrad::QuantLocal`]
//! variants; sparse formats are pre-split per shard with local indices
//! already (see `coordinator::compress`), exactly like the in-process
//! protocol.
//!
//! Every malformed input decodes to a typed [`WireError`] — truncation at
//! any offset, unknown tags, out-of-range sparse indices, bad UTF-8 —
//! never a panic and never a silently wrong payload (fuzzed in
//! `tests/property_transport.rs`).

use crate::coordinator::compress::{QuantGrad, ShardGrad, SparseGrad, SparseQuantGrad};
use crate::coordinator::params::{block_count, block_range, ParamDtype, ParamSnapshot, BLOCK_ELEMS};
use std::fmt;
use std::ops::Range;
use std::sync::Arc;

/// Message tags (frame payload byte 0).
const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_SUBMIT: u8 = 3;
const TAG_GRAD_ACK: u8 = 4;
const TAG_SNAP_REQ: u8 = 5;
const TAG_SNAP_SLICE: u8 = 6;
const TAG_HEARTBEAT: u8 = 7;
const TAG_SHUTDOWN: u8 = 8;
const TAG_LEAVE: u8 = 9;
const TAG_EVICT: u8 = 10;
const TAG_STATUS_REQ: u8 = 11;
const TAG_STATUS: u8 = 12;
const TAG_SUBSCRIBE: u8 = 13;
const TAG_STATUS_DELTA: u8 = 14;
const TAG_SNAP_DELTA: u8 = 15;

/// Gradient payload tags (inside `SubmitGrad`).
const GRAD_DENSE: u8 = 0;
const GRAD_SPARSE: u8 = 1;
const GRAD_QUANT: u8 = 2;
const GRAD_SPARSE_QUANT: u8 = 3;

/// `SubmitGrad` fixed header: tag (1) + shard (4) + seq (8) +
/// base_version (8) + loss (4).
pub const SUBMIT_HEADER_BYTES: usize = 25;

/// Per-format gradient headers inside a `SubmitGrad` payload.
pub const GRAD_DENSE_HEADER_BYTES: usize = 5; // tag + n
pub const GRAD_SPARSE_HEADER_BYTES: usize = 9; // tag + dim + nnz
pub const GRAD_QUANT_HEADER_BYTES: usize = 9; // tag + n + scale
pub const GRAD_SPARSE_QUANT_HEADER_BYTES: usize = 13; // tag + dim + scale + nnz

/// `SnapshotDelta` fixed header: tag (1) + shard (4) + version (8) +
/// dtype (1) + done (1) + block_elems (4) + nblocks (4).
pub const SNAP_DELTA_HEADER_BYTES: usize = 23;

/// Data-byte budget per `SnapshotDelta` chunk. Well under the 64 MiB frame
/// cap so a chunk (header + index/len tables + data) always fits one frame,
/// and small enough that serving a huge shard never buffers the whole slice.
pub const SNAP_CHUNK_BYTES: usize = 4 << 20;

/// Worker id in a `Hello` requesting a fresh assignment.
pub const WORKER_UNASSIGNED: u32 = u32::MAX;

/// A control-plane message. `SubmitGrad` carries a **shard-local** payload
/// (`DenseLocal` / `Sparse` / `QuantLocal` / `SparseQuant`).
#[derive(Clone, Debug)]
pub enum Msg {
    /// Client → server: join the run. `worker` is [`WORKER_UNASSIGNED`] for
    /// a first attach or a previously assigned id on reconnect; `shards` is
    /// the client's expected shard count (0 = unknown, server decides);
    /// `wire` is the worker's gradient wire format (`WireFormat` syntax),
    /// carried for telemetry/validation — decode is format-agnostic.
    Hello { worker: u32, shards: u32, wire: String },
    /// Server → client: attach accepted. Carries everything the worker
    /// needs to mirror the in-process configuration: its assigned id, the
    /// run's total worker count (data sharding), the PS shard count, the
    /// flat parameter dimension and whether this worker is in the delayed
    /// fraction (the paper's heterogeneity model assigns by id, so the
    /// server owns the draw).
    Welcome {
        worker: u32,
        workers: u32,
        shards: u32,
        dim: u64,
        delayed: bool,
    },
    /// Client → server: one shard's slice of a gradient submission. `seq`
    /// is the worker's submission counter (gap telemetry).
    SubmitGrad {
        shard: u32,
        seq: u64,
        base_version: u64,
        loss: f32,
        grad: ShardGrad,
    },
    /// Server → client: the O(1) version-token reply — the wire form of
    /// `server::Reply` (`changed = false` ⇔ `Reply::Unchanged`).
    GradAck {
        shard: u32,
        version: u64,
        changed: bool,
    },
    /// Client → server: send me shard `shard`'s parameters if newer than
    /// `version` (always answered; equal version returns the same slice).
    SnapshotRequest { shard: u32, version: u64 },
    /// Server → client: one shard's parameter slice at `version`.
    SnapshotSlice {
        shard: u32,
        version: u64,
        theta: Vec<f32>,
    },
    /// Either direction: liveness. A peer silent for longer than the
    /// heartbeat timeout is considered half-open and dropped.
    Heartbeat { seq: u64 },
    /// Server → client: the run is over; drain and exit cleanly.
    Shutdown,
    /// Client → server: clean departure of worker `worker`. Under elastic
    /// membership the server removes the worker from the barrier
    /// denominator immediately instead of waiting for the heartbeat
    /// timeout; the slot reopens for late joiners.
    Leave { worker: u32 },
    /// Server → client: this worker's slot is gone (reassigned, or the run
    /// is elastic and the worker was declared dead). Terminal: the client
    /// must not redial under the old identity — unlike the `Shutdown`
    /// refusal, which a reconnecting client retries through.
    Evict { worker: u32 },
    /// Client → server: read-only ops-plane probe — report the run's live
    /// status. Answerable before a `Hello` (a dashboard never takes a
    /// worker slot) and never touches the gradient plane.
    StatusRequest,
    /// Server → client: the status document, a UTF-8 JSON string (schema
    /// in DESIGN.md §2.9). JSON rather than fixed fields so dashboards can
    /// evolve without a wire-protocol bump.
    Status { json: String },
    /// Client → server: push-based ops plane — stream status documents at
    /// `interval_ms` (clamped server-side) instead of being polled. Like
    /// `StatusRequest`, answerable before a `Hello`; the first
    /// [`Msg::StatusDelta`] is pushed immediately on subscription.
    Subscribe { interval_ms: u32 },
    /// Server → client: one pushed status snapshot. `seq` numbers the
    /// deltas on this connection from 0, so a follower can detect gaps.
    /// The document is byte-identical to what a `StatusRequest` answered
    /// at the same instant would carry (DESIGN.md §2.11).
    StatusDelta { seq: u64, json: String },
    /// Server → client: one chunk of a versioned snapshot refresh
    /// (DESIGN.md §2.12). Carries the shard's parameter blocks newer than
    /// the requested version — or all blocks for a bootstrap request
    /// (`version` 0) — split across as many frames as needed, each well
    /// under the frame cap; `done` marks the final chunk of the response.
    /// `idx[i]` is a block index (coordinates `idx[i]·block_elems ..`),
    /// `lens[i]` its payload length in bytes, and `data` the concatenated
    /// little-endian coordinates in `dtype` precision.
    SnapshotDelta {
        shard: u32,
        version: u64,
        dtype: u8,
        done: bool,
        block_elems: u32,
        idx: Vec<u32>,
        lens: Vec<u32>,
        data: Vec<u8>,
    },
}

/// Typed decode errors for the message layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The payload ended before the field being read.
    Truncated { need: usize, have: usize },
    /// Unknown message tag.
    UnknownMsg(u8),
    /// Unknown gradient-payload tag.
    UnknownPayload(u8),
    /// Structurally valid but semantically impossible (index out of range,
    /// inconsistent lengths, bad UTF-8, trailing garbage).
    Invalid(String),
    /// Encode-side refusal: a length field would overflow its u32 wire
    /// representation. Returned instead of silently truncating with `as`.
    TooLong { what: &'static str, len: u64 },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated message: need {need} bytes, have {have}")
            }
            WireError::UnknownMsg(t) => write!(f, "unknown message tag {t}"),
            WireError::UnknownPayload(t) => write!(f, "unknown gradient payload tag {t}"),
            WireError::Invalid(why) => write!(f, "invalid message: {why}"),
            WireError::TooLong { what, len } => {
                write!(f, "{what} length {len} exceeds the u32 wire limit")
            }
        }
    }
}

impl std::error::Error for WireError {}

// ---- primitive writers ---------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Write a usize length as u32, refusing (typed error, no silent `as`
/// truncation) anything that does not fit. On error the buffer holds a
/// partial message the caller must discard, never send.
fn put_len_u32(out: &mut Vec<u8>, len: usize, what: &'static str) -> Result<(), WireError> {
    let v = u32::try_from(len).map_err(|_| WireError::TooLong {
        what,
        len: len as u64,
    })?;
    put_u32(out, v);
    Ok(())
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    out.reserve(vs.len() * 4);
    for &v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_u32s(out: &mut Vec<u8>, vs: &[u32]) {
    out.reserve(vs.len() * 4);
    for &v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_i8s(out: &mut Vec<u8>, vs: &[i8]) {
    out.reserve(vs.len());
    for &v in vs {
        out.push(v as u8);
    }
}

// ---- primitive reader ----------------------------------------------------

struct Rd<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b, off: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let have = self.b.len() - self.off;
        if have < n {
            return Err(WireError::Truncated {
                need: self.off + n,
                have: self.b.len(),
            });
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        let s = self.take(4)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        let s = self.take(n.checked_mul(4).ok_or_else(|| {
            WireError::Invalid(format!("count {n} overflows"))
        })?)?;
        Ok(s.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>, WireError> {
        let s = self.take(n.checked_mul(4).ok_or_else(|| {
            WireError::Invalid(format!("count {n} overflows"))
        })?)?;
        Ok(s.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn i8s(&mut self, n: usize) -> Result<Vec<i8>, WireError> {
        let s = self.take(n)?;
        Ok(s.iter().map(|&b| b as i8).collect())
    }

    fn done(&self) -> Result<(), WireError> {
        if self.off != self.b.len() {
            return Err(WireError::Invalid(format!(
                "{} trailing bytes after message",
                self.b.len() - self.off
            )));
        }
        Ok(())
    }
}

// ---- gradient payload ----------------------------------------------------

/// Append the shard-local encoding of one shard's portion of `grad` to
/// `out`. `range` is the shard's slice of the flat θ; full-dimension
/// payloads are cut to it, shard-local payloads (pre-split sparse, or
/// payloads that already came off the wire) are written as-is.
pub fn encode_grad_into(
    grad: &ShardGrad,
    range: Range<usize>,
    out: &mut Vec<u8>,
) -> Result<(), WireError> {
    match grad {
        ShardGrad::Dense(g) => {
            out.push(GRAD_DENSE);
            let slice = &g[range];
            put_len_u32(out, slice.len(), "dense gradient")?;
            put_f32s(out, slice);
        }
        ShardGrad::DenseLocal(g) => {
            out.push(GRAD_DENSE);
            put_len_u32(out, g.len(), "dense gradient")?;
            put_f32s(out, g);
        }
        ShardGrad::Sparse(s) => {
            out.push(GRAD_SPARSE);
            put_len_u32(out, s.dim, "sparse shard dim")?;
            put_len_u32(out, s.idx.len(), "sparse nnz")?;
            put_u32s(out, &s.idx);
            put_f32s(out, &s.val);
        }
        ShardGrad::Quant(q) => {
            out.push(GRAD_QUANT);
            let slice = &q.data[range];
            put_len_u32(out, slice.len(), "quantized gradient")?;
            put_f32(out, q.scale);
            put_i8s(out, slice);
        }
        ShardGrad::QuantLocal(q) => {
            out.push(GRAD_QUANT);
            put_len_u32(out, q.data.len(), "quantized gradient")?;
            put_f32(out, q.scale);
            put_i8s(out, &q.data);
        }
        ShardGrad::SparseQuant(s) => {
            out.push(GRAD_SPARSE_QUANT);
            put_len_u32(out, s.dim, "sparse-quant shard dim")?;
            put_f32(out, s.scale);
            put_len_u32(out, s.idx.len(), "sparse-quant nnz")?;
            put_u32s(out, &s.idx);
            put_i8s(out, &s.data);
        }
    }
    Ok(())
}

/// Decode a shard-local gradient payload. Sparse indices are validated
/// against the declared dimension so a corrupt-but-CRC-colliding payload
/// can never scatter-add out of bounds.
fn decode_grad(r: &mut Rd) -> Result<ShardGrad, WireError> {
    match r.u8()? {
        GRAD_DENSE => {
            let n = r.u32()? as usize;
            Ok(ShardGrad::DenseLocal(Arc::new(r.f32s(n)?)))
        }
        GRAD_SPARSE => {
            let dim = r.u32()? as usize;
            let nnz = r.u32()? as usize;
            if nnz > dim {
                return Err(WireError::Invalid(format!(
                    "sparse nnz {nnz} exceeds shard dim {dim}"
                )));
            }
            let idx = r.u32s(nnz)?;
            let val = r.f32s(nnz)?;
            if let Some(&bad) = idx.iter().find(|&&i| i as usize >= dim) {
                return Err(WireError::Invalid(format!(
                    "sparse index {bad} out of range for shard dim {dim}"
                )));
            }
            Ok(ShardGrad::Sparse(Arc::new(SparseGrad { dim, idx, val })))
        }
        GRAD_QUANT => {
            let n = r.u32()? as usize;
            let scale = r.f32()?;
            Ok(ShardGrad::QuantLocal(Arc::new(QuantGrad {
                scale,
                data: r.i8s(n)?,
            })))
        }
        GRAD_SPARSE_QUANT => {
            let dim = r.u32()? as usize;
            let scale = r.f32()?;
            let nnz = r.u32()? as usize;
            if nnz > dim {
                return Err(WireError::Invalid(format!(
                    "sparse-quant nnz {nnz} exceeds shard dim {dim}"
                )));
            }
            let idx = r.u32s(nnz)?;
            let data = r.i8s(nnz)?;
            if let Some(&bad) = idx.iter().find(|&&i| i as usize >= dim) {
                return Err(WireError::Invalid(format!(
                    "sparse-quant index {bad} out of range for shard dim {dim}"
                )));
            }
            Ok(ShardGrad::SparseQuant(Arc::new(SparseQuantGrad {
                dim,
                idx,
                scale,
                data,
            })))
        }
        t => Err(WireError::UnknownPayload(t)),
    }
}

// ---- message encode / decode ---------------------------------------------

/// Encode a `SubmitGrad` without constructing a [`Msg`] — the worker hot
/// path. Clears and refills `out` (reused round-trip, no steady-state
/// allocation). `range` is the destination shard's slice of the flat θ.
#[allow(clippy::too_many_arguments)]
pub fn encode_submit_into(
    shard: u32,
    seq: u64,
    base_version: u64,
    loss: f32,
    grad: &ShardGrad,
    range: Range<usize>,
    out: &mut Vec<u8>,
) -> Result<(), WireError> {
    out.clear();
    out.push(TAG_SUBMIT);
    put_u32(out, shard);
    put_u64(out, seq);
    put_u64(out, base_version);
    put_f32(out, loss);
    encode_grad_into(grad, range, out)
}

/// Encode a `SnapshotSlice` without constructing a [`Msg`] — the serving
/// hot path answers snapshot requests straight out of a cell's published
/// `Arc<ParamSnapshot>` without cloning θ. Clears and refills `out`;
/// byte-identical to `Msg::SnapshotSlice { .. }.encode_into(out)`.
pub fn encode_snapshot_slice_into(
    shard: u32,
    version: u64,
    theta: &[f32],
    out: &mut Vec<u8>,
) -> Result<(), WireError> {
    out.clear();
    out.push(TAG_SNAP_SLICE);
    put_u32(out, shard);
    put_u64(out, version);
    put_len_u32(out, theta.len(), "snapshot slice")?;
    put_f32s(out, theta);
    Ok(())
}

/// Size in bytes of the legacy full-slice encoding of `len` parameters
/// (message payload only, before framing).
pub fn snapshot_slice_bytes(len: usize) -> usize {
    17 + 4 * len // tag + shard + version + count + payload
}

/// Whether a snapshot is served as one legacy full [`Msg::SnapshotSlice`]
/// (f32, slice payload within `full_max`) rather than chunked deltas —
/// the predicate half of [`snapshot_response_msgs`], exposed so the
/// reactor can take its zero-copy encode path for exactly those replies.
pub fn snapshot_serves_full(snap: &ParamSnapshot, full_max: usize) -> bool {
    snap.dtype() == ParamDtype::F32 && snapshot_slice_bytes(snap.len()) <= full_max
}

/// Build the frames answering one `SnapshotRequest { version: have }` from
/// a published snapshot — the serving rule shared by the threaded and
/// reactor frontends.
///
/// Small f32 shards (full slice payload ≤ `full_max` bytes) keep the legacy
/// single-frame [`Msg::SnapshotSlice`], byte-identical to the pre-delta
/// protocol. Everything else — oversized slices that used to poison the
/// stream with `FrameError::TooLarge`, and all half-precision snapshots —
/// is served as chunked [`Msg::SnapshotDelta`]s: the blocks newer than
/// `have` (all blocks for a bootstrap `have == 0` or an inconsistent
/// `have > version`), at most [`SNAP_CHUNK_BYTES`] of data per frame, last
/// chunk flagged `done`.
pub fn snapshot_response_msgs(
    shard: u32,
    snap: &ParamSnapshot,
    have: u64,
    full_max: usize,
) -> Vec<Msg> {
    let len = snap.len();
    if snapshot_serves_full(snap, full_max) {
        return vec![Msg::SnapshotSlice {
            shard,
            version: snap.version,
            theta: snap.theta().to_vec(),
        }];
    }
    let elem_bytes = snap.dtype().elem_bytes();
    let blocks: Vec<usize> = if have == 0 || have > snap.version {
        // Bootstrap (the client's buffer contents are unknown to us) or a
        // version from another life: send everything.
        (0..block_count(len)).collect()
    } else {
        snap.blocks_newer_than(have)
    };
    let mut msgs = Vec::new();
    let mut i = 0;
    loop {
        let mut idx = Vec::new();
        let mut lens = Vec::new();
        let mut data = Vec::new();
        while i < blocks.len() && data.len() < SNAP_CHUNK_BYTES {
            let b = blocks[i];
            let r = block_range(b, len);
            idx.push(b as u32);
            lens.push((r.len() * elem_bytes) as u32);
            snap.data.extend_wire_bytes(r, &mut data);
            i += 1;
        }
        let done = i >= blocks.len();
        msgs.push(Msg::SnapshotDelta {
            shard,
            version: snap.version,
            dtype: snap.dtype().tag(),
            done,
            block_elems: BLOCK_ELEMS as u32,
            idx,
            lens,
            data,
        });
        if done {
            break;
        }
    }
    msgs
}

/// Apply one decoded [`Msg::SnapshotDelta`] chunk to a client-side f32
/// buffer holding the shard's full slice. Geometry is validated against
/// `out.len()` (the dimension from the handshake), so a corrupt chunk can
/// never write out of bounds or leave a half-written block.
pub fn apply_snapshot_delta(
    dtype: u8,
    block_elems: u32,
    idx: &[u32],
    lens: &[u32],
    data: &[u8],
    out: &mut [f32],
) -> Result<(), WireError> {
    let d = ParamDtype::from_tag(dtype)
        .ok_or_else(|| WireError::Invalid(format!("unknown snapshot dtype tag {dtype}")))?;
    let be = block_elems as usize;
    if be == 0 {
        return Err(WireError::Invalid("snapshot block_elems is zero".into()));
    }
    let mut off = 0usize;
    for (&b, &l) in idx.iter().zip(lens) {
        let start = (b as usize).checked_mul(be).filter(|&s| s < out.len()).ok_or_else(
            || WireError::Invalid(format!("snapshot delta block {b} out of range")),
        )?;
        let end = (start + be).min(out.len());
        let want = (end - start) * d.elem_bytes();
        if l as usize != want {
            return Err(WireError::Invalid(format!(
                "snapshot delta block {b}: got {l} bytes, shard geometry wants {want}"
            )));
        }
        let chunk = data.get(off..off + want).ok_or(WireError::Truncated {
            need: off + want,
            have: data.len(),
        })?;
        crate::coordinator::params::decode_block_into(d, chunk, &mut out[start..end]);
        off += want;
    }
    if off != data.len() {
        return Err(WireError::Invalid(format!(
            "{} trailing bytes after snapshot delta blocks",
            data.len() - off
        )));
    }
    Ok(())
}

impl Msg {
    /// Encode into `out` (cleared and refilled). For `SubmitGrad` the
    /// payload must already be shard-local (as decoded payloads are); the
    /// worker's encode path uses [`encode_submit_into`] to slice full-dim
    /// payloads without an intermediate `Msg`. Fails (typed, no silent
    /// truncation) if any length field overflows u32; the buffer then
    /// holds a partial message the caller must discard.
    pub fn encode_into(&self, out: &mut Vec<u8>) -> Result<(), WireError> {
        out.clear();
        match self {
            Msg::Hello {
                worker,
                shards,
                wire,
            } => {
                out.push(TAG_HELLO);
                put_u32(out, *worker);
                put_u32(out, *shards);
                put_len_u32(out, wire.len(), "hello wire string")?;
                out.extend_from_slice(wire.as_bytes());
            }
            Msg::Welcome {
                worker,
                workers,
                shards,
                dim,
                delayed,
            } => {
                out.push(TAG_WELCOME);
                put_u32(out, *worker);
                put_u32(out, *workers);
                put_u32(out, *shards);
                put_u64(out, *dim);
                out.push(u8::from(*delayed));
            }
            Msg::SubmitGrad {
                shard,
                seq,
                base_version,
                loss,
                grad,
            } => {
                out.push(TAG_SUBMIT);
                put_u32(out, *shard);
                put_u64(out, *seq);
                put_u64(out, *base_version);
                put_f32(out, *loss);
                // Payload is shard-local by contract: encode its full
                // extent. The range end is not used for local variants.
                let len = match grad {
                    ShardGrad::Dense(g) => g.len(),
                    ShardGrad::DenseLocal(g) => g.len(),
                    ShardGrad::Quant(q) => q.data.len(),
                    ShardGrad::QuantLocal(q) => q.data.len(),
                    ShardGrad::Sparse(s) => s.dim,
                    ShardGrad::SparseQuant(s) => s.dim,
                };
                encode_grad_into(grad, 0..len, out)?;
            }
            Msg::GradAck {
                shard,
                version,
                changed,
            } => {
                out.push(TAG_GRAD_ACK);
                put_u32(out, *shard);
                put_u64(out, *version);
                out.push(u8::from(*changed));
            }
            Msg::SnapshotRequest { shard, version } => {
                out.push(TAG_SNAP_REQ);
                put_u32(out, *shard);
                put_u64(out, *version);
            }
            Msg::SnapshotSlice {
                shard,
                version,
                theta,
            } => {
                out.push(TAG_SNAP_SLICE);
                put_u32(out, *shard);
                put_u64(out, *version);
                put_len_u32(out, theta.len(), "snapshot slice")?;
                put_f32s(out, theta);
            }
            Msg::Heartbeat { seq } => {
                out.push(TAG_HEARTBEAT);
                put_u64(out, *seq);
            }
            Msg::Shutdown => out.push(TAG_SHUTDOWN),
            Msg::Leave { worker } => {
                out.push(TAG_LEAVE);
                put_u32(out, *worker);
            }
            Msg::Evict { worker } => {
                out.push(TAG_EVICT);
                put_u32(out, *worker);
            }
            Msg::StatusRequest => out.push(TAG_STATUS_REQ),
            Msg::Status { json } => {
                out.push(TAG_STATUS);
                put_len_u32(out, json.len(), "status document")?;
                out.extend_from_slice(json.as_bytes());
            }
            Msg::Subscribe { interval_ms } => {
                out.push(TAG_SUBSCRIBE);
                put_u32(out, *interval_ms);
            }
            Msg::StatusDelta { seq, json } => {
                out.push(TAG_STATUS_DELTA);
                put_u64(out, *seq);
                put_len_u32(out, json.len(), "status delta")?;
                out.extend_from_slice(json.as_bytes());
            }
            Msg::SnapshotDelta {
                shard,
                version,
                dtype,
                done,
                block_elems,
                idx,
                lens,
                data,
            } => {
                out.push(TAG_SNAP_DELTA);
                put_u32(out, *shard);
                put_u64(out, *version);
                out.push(*dtype);
                out.push(u8::from(*done));
                put_u32(out, *block_elems);
                debug_assert_eq!(idx.len(), lens.len());
                put_len_u32(out, idx.len(), "snapshot delta block count")?;
                put_u32s(out, idx);
                put_u32s(out, lens);
                out.extend_from_slice(data);
            }
        }
        Ok(())
    }

    /// Decode one message from a frame payload. Rejects trailing garbage
    /// (a frame carries exactly one message).
    pub fn decode(buf: &[u8]) -> Result<Msg, WireError> {
        let mut r = Rd::new(buf);
        let msg = match r.u8()? {
            TAG_HELLO => {
                let worker = r.u32()?;
                let shards = r.u32()?;
                let n = r.u32()? as usize;
                let wire = std::str::from_utf8(r.take(n)?)
                    .map_err(|_| WireError::Invalid("hello wire format is not UTF-8".into()))?
                    .to_string();
                Msg::Hello {
                    worker,
                    shards,
                    wire,
                }
            }
            TAG_WELCOME => Msg::Welcome {
                worker: r.u32()?,
                workers: r.u32()?,
                shards: r.u32()?,
                dim: r.u64()?,
                delayed: r.u8()? != 0,
            },
            TAG_SUBMIT => Msg::SubmitGrad {
                shard: r.u32()?,
                seq: r.u64()?,
                base_version: r.u64()?,
                loss: r.f32()?,
                grad: decode_grad(&mut r)?,
            },
            TAG_GRAD_ACK => Msg::GradAck {
                shard: r.u32()?,
                version: r.u64()?,
                changed: r.u8()? != 0,
            },
            TAG_SNAP_REQ => Msg::SnapshotRequest {
                shard: r.u32()?,
                version: r.u64()?,
            },
            TAG_SNAP_SLICE => {
                let shard = r.u32()?;
                let version = r.u64()?;
                let n = r.u32()? as usize;
                Msg::SnapshotSlice {
                    shard,
                    version,
                    theta: r.f32s(n)?,
                }
            }
            TAG_HEARTBEAT => Msg::Heartbeat { seq: r.u64()? },
            TAG_SHUTDOWN => Msg::Shutdown,
            TAG_LEAVE => Msg::Leave { worker: r.u32()? },
            TAG_EVICT => Msg::Evict { worker: r.u32()? },
            TAG_STATUS_REQ => Msg::StatusRequest,
            TAG_STATUS => {
                let n = r.u32()? as usize;
                let json = std::str::from_utf8(r.take(n)?)
                    .map_err(|_| WireError::Invalid("status document is not UTF-8".into()))?
                    .to_string();
                Msg::Status { json }
            }
            TAG_SUBSCRIBE => Msg::Subscribe {
                interval_ms: r.u32()?,
            },
            TAG_STATUS_DELTA => {
                let seq = r.u64()?;
                let n = r.u32()? as usize;
                let json = std::str::from_utf8(r.take(n)?)
                    .map_err(|_| WireError::Invalid("status delta is not UTF-8".into()))?
                    .to_string();
                Msg::StatusDelta { seq, json }
            }
            TAG_SNAP_DELTA => {
                let shard = r.u32()?;
                let version = r.u64()?;
                let dtype = r.u8()?;
                let Some(d) = ParamDtype::from_tag(dtype) else {
                    return Err(WireError::Invalid(format!(
                        "unknown snapshot dtype tag {dtype}"
                    )));
                };
                let done = r.u8()? != 0;
                let block_elems = r.u32()?;
                if block_elems == 0 {
                    return Err(WireError::Invalid("snapshot block_elems is zero".into()));
                }
                let n = r.u32()? as usize;
                let idx = r.u32s(n)?;
                let lens = r.u32s(n)?;
                let max_block = block_elems as usize * d.elem_bytes();
                let mut total = 0usize;
                for (&b, &l) in idx.iter().zip(&lens) {
                    let l = l as usize;
                    if l == 0 || l > max_block || l % d.elem_bytes() != 0 {
                        return Err(WireError::Invalid(format!(
                            "snapshot delta block {b} has bad length {l}"
                        )));
                    }
                    total = total.checked_add(l).ok_or_else(|| {
                        WireError::Invalid("snapshot delta lengths overflow".into())
                    })?;
                }
                let data = r.take(total)?.to_vec();
                Msg::SnapshotDelta {
                    shard,
                    version,
                    dtype,
                    done,
                    block_elems,
                    idx,
                    lens,
                    data,
                }
            }
            t => return Err(WireError::UnknownMsg(t)),
        };
        r.done()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Msg) -> Msg {
        let mut buf = Vec::new();
        msg.encode_into(&mut buf).unwrap();
        Msg::decode(&buf).expect("roundtrip decode")
    }

    #[test]
    fn control_messages_roundtrip_exhaustively() {
        // Hello
        let m = roundtrip(&Msg::Hello {
            worker: WORKER_UNASSIGNED,
            shards: 4,
            wire: "topk:0.01".into(),
        });
        match m {
            Msg::Hello {
                worker,
                shards,
                wire,
            } => {
                assert_eq!(worker, WORKER_UNASSIGNED);
                assert_eq!(shards, 4);
                assert_eq!(wire, "topk:0.01");
            }
            other => panic!("{other:?}"),
        }
        // Welcome
        let m = roundtrip(&Msg::Welcome {
            worker: 3,
            workers: 8,
            shards: 2,
            dim: 111_936,
            delayed: true,
        });
        match m {
            Msg::Welcome {
                worker,
                workers,
                shards,
                dim,
                delayed,
            } => {
                assert_eq!((worker, workers, shards, dim, delayed), (3, 8, 2, 111_936, true));
            }
            other => panic!("{other:?}"),
        }
        // GradAck
        let m = roundtrip(&Msg::GradAck {
            shard: 1,
            version: 42,
            changed: false,
        });
        match m {
            Msg::GradAck {
                shard,
                version,
                changed,
            } => assert_eq!((shard, version, changed), (1, 42, false)),
            other => panic!("{other:?}"),
        }
        // SnapshotRequest
        let m = roundtrip(&Msg::SnapshotRequest {
            shard: 7,
            version: u64::MAX,
        });
        match m {
            Msg::SnapshotRequest { shard, version } => {
                assert_eq!((shard, version), (7, u64::MAX))
            }
            other => panic!("{other:?}"),
        }
        // SnapshotSlice (with a NaN: bit-exact float transport)
        let theta = vec![1.5f32, -0.0, f32::NAN, f32::MIN_POSITIVE];
        let m = roundtrip(&Msg::SnapshotSlice {
            shard: 0,
            version: 9,
            theta: theta.clone(),
        });
        match m {
            Msg::SnapshotSlice {
                shard,
                version,
                theta: got,
            } => {
                assert_eq!((shard, version), (0, 9));
                assert_eq!(got.len(), theta.len());
                for (a, b) in got.iter().zip(&theta) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
        // Heartbeat + Shutdown
        assert!(matches!(
            roundtrip(&Msg::Heartbeat { seq: 12345 }),
            Msg::Heartbeat { seq: 12345 }
        ));
        assert!(matches!(roundtrip(&Msg::Shutdown), Msg::Shutdown));
        // Leave + Evict (elastic membership control plane)
        assert!(matches!(
            roundtrip(&Msg::Leave { worker: 6 }),
            Msg::Leave { worker: 6 }
        ));
        assert!(matches!(
            roundtrip(&Msg::Evict { worker: 2 }),
            Msg::Evict { worker: 2 }
        ));
        // truncated membership messages are typed errors, not panics
        let mut buf = Vec::new();
        Msg::Leave { worker: 6 }.encode_into(&mut buf).unwrap();
        assert!(matches!(
            Msg::decode(&buf[..3]),
            Err(WireError::Truncated { .. })
        ));
        // StatusRequest + Status (the read-only ops plane)
        assert!(matches!(roundtrip(&Msg::StatusRequest), Msg::StatusRequest));
        let doc = r#"{"workers":{"active":3},"shards":[{"k":2}]}"#;
        match roundtrip(&Msg::Status { json: doc.into() }) {
            Msg::Status { json } => assert_eq!(json, doc),
            other => panic!("{other:?}"),
        }
        // non-empty unicode survives (the doc may carry escaped keys)
        match roundtrip(&Msg::Status { json: "{\"é\":1}".into() }) {
            Msg::Status { json } => assert_eq!(json, "{\"é\":1}"),
            other => panic!("{other:?}"),
        }
        // truncated status documents are typed errors, not panics
        let mut buf = Vec::new();
        Msg::Status { json: doc.into() }.encode_into(&mut buf).unwrap();
        for cut in [1, 4, buf.len() - 1] {
            assert!(matches!(
                Msg::decode(&buf[..cut]),
                Err(WireError::Truncated { .. })
            ));
        }
        // trailing garbage after a StatusRequest is rejected
        let mut sr = Vec::new();
        Msg::StatusRequest.encode_into(&mut sr).unwrap();
        sr.push(7);
        assert!(matches!(Msg::decode(&sr), Err(WireError::Invalid(_))));
    }

    #[test]
    fn subscription_messages_roundtrip_and_reject_malformed_frames() {
        // Subscribe carries the requested push interval verbatim.
        for interval_ms in [0u32, 1, 250, u32::MAX] {
            match roundtrip(&Msg::Subscribe { interval_ms }) {
                Msg::Subscribe { interval_ms: i } => assert_eq!(i, interval_ms),
                other => panic!("{other:?}"),
            }
        }
        // StatusDelta: sequence number + the pushed document.
        let doc = r#"{"workers":{"active":2},"stages":{"apply":{"count":7}}}"#;
        match roundtrip(&Msg::StatusDelta { seq: 41, json: doc.into() }) {
            Msg::StatusDelta { seq, json } => {
                assert_eq!(seq, 41);
                assert_eq!(json, doc);
            }
            other => panic!("{other:?}"),
        }
        // Truncations anywhere in the frame are typed errors, not panics.
        let mut buf = Vec::new();
        Msg::StatusDelta { seq: 7, json: doc.into() }.encode_into(&mut buf).unwrap();
        for cut in [1, 5, 9, 12, buf.len() - 1] {
            assert!(matches!(
                Msg::decode(&buf[..cut]),
                Err(WireError::Truncated { .. })
            ));
        }
        // A delta whose payload is not UTF-8 is rejected as Invalid.
        let mut bad = Vec::new();
        bad.push(TAG_STATUS_DELTA);
        put_u64(&mut bad, 0);
        put_u32(&mut bad, 2);
        bad.extend_from_slice(&[0xFF, 0xFE]);
        assert!(matches!(Msg::decode(&bad), Err(WireError::Invalid(_))));
        // Trailing garbage after a Subscribe is rejected.
        let mut sub = Vec::new();
        Msg::Subscribe { interval_ms: 100 }.encode_into(&mut sub).unwrap();
        sub.push(0);
        assert!(matches!(Msg::decode(&sub), Err(WireError::Invalid(_))));
    }

    #[test]
    fn submit_roundtrips_every_payload_kind() {
        let dense = ShardGrad::Dense(Arc::new(vec![1.0f32, -2.0, 3.0, 0.5]));
        let sparse = ShardGrad::Sparse(Arc::new(SparseGrad {
            dim: 4,
            idx: vec![0, 3],
            val: vec![0.25, -0.75],
        }));
        let quant = ShardGrad::Quant(Arc::new(QuantGrad {
            scale: 0.5,
            data: vec![1, -1, 127, -127],
        }));
        let sq = ShardGrad::SparseQuant(Arc::new(SparseQuantGrad {
            dim: 4,
            idx: vec![1, 2],
            scale: 0.25,
            data: vec![-4, 8],
        }));
        for (grad, range) in [
            (dense, 1..3usize), // full-dim payload: only the slice travels
            (sparse, 0..4),
            (quant, 1..3),
            (sq, 0..4),
        ] {
            let mut buf = Vec::new();
            encode_submit_into(2, 77, 5, 0.125, &grad, range.clone(), &mut buf).unwrap();
            let msg = Msg::decode(&buf).unwrap();
            let Msg::SubmitGrad {
                shard,
                seq,
                base_version,
                loss,
                grad: got,
            } = msg
            else {
                panic!("expected SubmitGrad");
            };
            assert_eq!((shard, seq, base_version), (2, 77, 5));
            assert_eq!(loss, 0.125);
            // The decoded (shard-local) payload views identically to the
            // original sliced to the shard's range.
            let shard_len = range.len();
            let mut want = vec![0.0f32; shard_len];
            grad.view(range).add_to(&mut want);
            let mut have = vec![0.0f32; shard_len];
            got.view(0..shard_len).add_to(&mut have);
            for (a, b) in want.iter().zip(&have) {
                assert_eq!(a.to_bits(), b.to_bits(), "{grad:?}");
            }
            // byte accounting survives the trip
            assert_eq!(grad.wire_bytes(shard_len), got.wire_bytes(shard_len));
            // re-encoding the decoded (local) payload is byte-identical
            let mut again = Vec::new();
            encode_submit_into(2, 77, 5, 0.125, &got, 0..shard_len, &mut again).unwrap();
            assert_eq!(buf, again);
        }
    }

    #[test]
    fn decode_rejects_unknown_tags_and_garbage() {
        assert!(matches!(
            Msg::decode(&[99]),
            Err(WireError::UnknownMsg(99))
        ));
        // unknown gradient payload tag inside a submit
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.0,
            &ShardGrad::DenseLocal(Arc::new(vec![1.0])),
            0..1,
            &mut buf,
        ).unwrap();
        buf[SUBMIT_HEADER_BYTES] = 200;
        assert!(matches!(
            Msg::decode(&buf),
            Err(WireError::UnknownPayload(200))
        ));
        // trailing garbage after a well-formed message
        let mut hb = Vec::new();
        Msg::Heartbeat { seq: 1 }.encode_into(&mut hb).unwrap();
        hb.push(0);
        assert!(matches!(Msg::decode(&hb), Err(WireError::Invalid(_))));
        // empty payload
        assert!(matches!(
            Msg::decode(&[]),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn sparse_indices_are_range_checked() {
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            1,
            0,
            0.0,
            &ShardGrad::Sparse(Arc::new(SparseGrad {
                dim: 4,
                idx: vec![3],
                val: vec![1.0],
            })),
            0..4,
            &mut buf,
        ).unwrap();
        // Patch the index to 4 (== dim, out of range). Layout after the
        // submit + sparse headers: idx array first.
        let idx_off = SUBMIT_HEADER_BYTES + GRAD_SPARSE_HEADER_BYTES;
        buf[idx_off..idx_off + 4].copy_from_slice(&4u32.to_le_bytes());
        match Msg::decode(&buf) {
            Err(WireError::Invalid(why)) => assert!(why.contains("out of range"), "{why}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
        // nnz > dim is rejected before reading the arrays
        let mut buf2 = Vec::new();
        encode_submit_into(
            0,
            1,
            0,
            0.0,
            &ShardGrad::Sparse(Arc::new(SparseGrad {
                dim: 2,
                idx: vec![0, 1],
                val: vec![1.0, 2.0],
            })),
            0..2,
            &mut buf2,
        ).unwrap();
        let nnz_off = SUBMIT_HEADER_BYTES + 5; // tag + dim
        buf2[nnz_off..nnz_off + 4].copy_from_slice(&3u32.to_le_bytes());
        assert!(matches!(Msg::decode(&buf2), Err(WireError::Invalid(_))));
    }

    #[test]
    fn header_byte_constants_match_the_encoder() {
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.0,
            &ShardGrad::DenseLocal(Arc::new(vec![0.0; 10])),
            0..10,
            &mut buf,
        )
        .unwrap();
        assert_eq!(buf.len(), SUBMIT_HEADER_BYTES + GRAD_DENSE_HEADER_BYTES + 40);
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.0,
            &ShardGrad::Sparse(Arc::new(SparseGrad {
                dim: 10,
                idx: vec![1, 2, 3],
                val: vec![0.0; 3],
            })),
            0..10,
            &mut buf,
        )
        .unwrap();
        assert_eq!(
            buf.len(),
            SUBMIT_HEADER_BYTES + GRAD_SPARSE_HEADER_BYTES + 3 * 8
        );
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.0,
            &ShardGrad::QuantLocal(Arc::new(QuantGrad {
                scale: 1.0,
                data: vec![0; 10],
            })),
            0..10,
            &mut buf,
        )
        .unwrap();
        assert_eq!(buf.len(), SUBMIT_HEADER_BYTES + GRAD_QUANT_HEADER_BYTES + 10);
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.0,
            &ShardGrad::SparseQuant(Arc::new(SparseQuantGrad {
                dim: 10,
                idx: vec![1, 2],
                scale: 1.0,
                data: vec![0, 0],
            })),
            0..10,
            &mut buf,
        ).unwrap();
        assert_eq!(
            buf.len(),
            SUBMIT_HEADER_BYTES + GRAD_SPARSE_QUANT_HEADER_BYTES + 2 * 5
        );
    }

    #[test]
    fn snapshot_delta_roundtrips_bitwise() {
        use crate::coordinator::params::{ParamStore, BLOCK_ELEMS};
        let dim = 2 * BLOCK_ELEMS + 33;
        let mut ps = ParamStore::new((0..dim).map(|i| (i as f32).cos()).collect(), 0.1);
        ps.apply_single(&vec![0.5; dim]);
        let snap = ps.cell().load();
        // Force the delta path with a tiny full_max.
        let msgs = snapshot_response_msgs(3, &snap, 0, 0);
        assert!(!msgs.is_empty());
        let mut out = vec![0.0f32; dim];
        for (i, m) in msgs.iter().enumerate() {
            let rt = roundtrip(m);
            let Msg::SnapshotDelta {
                shard,
                version,
                dtype,
                done,
                block_elems,
                idx,
                lens,
                data,
            } = rt
            else {
                panic!("expected SnapshotDelta");
            };
            assert_eq!(shard, 3);
            assert_eq!(version, snap.version);
            assert_eq!(done, i == msgs.len() - 1);
            apply_snapshot_delta(dtype, block_elems, &idx, &lens, &data, &mut out).unwrap();
        }
        for (a, b) in out.iter().zip(snap.theta()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn snapshot_delta_serves_only_stale_blocks() {
        use crate::coordinator::compress::GradView;
        use crate::coordinator::params::{ParamStore, BLOCK_ELEMS};
        let dim = 4 * BLOCK_ELEMS;
        let mut ps = ParamStore::new(vec![0.0; dim], 1.0);
        ps.apply_single(&vec![1.0; dim]); // v1: everything moves
        ps.apply_view(GradView::Sparse {
            idx: &[(2 * BLOCK_ELEMS) as u32],
            val: &[1.0],
        }); // v2: only block 2
        let snap = ps.cell().load();
        // A reader at v1 needs only block 2.
        let msgs = snapshot_response_msgs(0, &snap, 1, 0);
        assert_eq!(msgs.len(), 1);
        let Msg::SnapshotDelta { ref idx, done, .. } = msgs[0] else {
            panic!("expected SnapshotDelta");
        };
        assert!(done);
        assert_eq!(idx, &[2]);
        // A bootstrap reader (version 0) gets every block even though
        // blocks 0,1,3 have block_version 1 > 0 anyway; more importantly a
        // reader claiming a *future* version is treated as bootstrap.
        let msgs = snapshot_response_msgs(0, &snap, 99, 0);
        let Msg::SnapshotDelta { ref idx, .. } = msgs[0] else {
            panic!("expected SnapshotDelta");
        };
        assert_eq!(idx.len(), 4);
        // A reader already current gets an empty terminal chunk.
        let msgs = snapshot_response_msgs(0, &snap, snap.version, 0);
        assert_eq!(msgs.len(), 1);
        let Msg::SnapshotDelta { ref idx, done, .. } = msgs[0] else {
            panic!("expected SnapshotDelta");
        };
        assert!(done);
        assert!(idx.is_empty());
    }

    #[test]
    fn snapshot_response_keeps_legacy_slice_for_small_f32_shards() {
        use crate::coordinator::params::ParamStore;
        let mut ps = ParamStore::new(vec![1.0, 2.0], 0.5);
        ps.apply_single(&[1.0, 1.0]);
        let snap = ps.cell().load();
        let msgs = snapshot_response_msgs(1, &snap, 0, super::super::frame::MAX_PAYLOAD);
        assert_eq!(msgs.len(), 1);
        let Msg::SnapshotSlice {
            shard,
            version,
            ref theta,
        } = msgs[0]
        else {
            panic!("expected legacy SnapshotSlice, got {:?}", msgs[0]);
        };
        assert_eq!((shard, version), (1, 1));
        assert_eq!(theta[..], [0.5, 1.5]);
    }

    #[test]
    fn snapshot_delta_chunks_respect_the_budget() {
        use crate::coordinator::params::{ParamStore, BLOCK_ELEMS};
        // 3 blocks of data but a budget of ~1 block forces one block per
        // chunk: the chunking loop stops adding once the budget is met.
        let dim = 3 * BLOCK_ELEMS;
        let mut ps = ParamStore::new(vec![0.0; dim], 1.0);
        ps.apply_single(&vec![1.0; dim]);
        let snap = ps.cell().load();
        let msgs = snapshot_response_msgs(0, &snap, 0, 0);
        // SNAP_CHUNK_BYTES is 4 MiB and a block is 16 KiB, so all three fit
        // in one chunk here; the budget path is exercised with real sizes in
        // the transport integration tests. Still: every chunk's data must
        // stay under budget + one block.
        for m in &msgs {
            let Msg::SnapshotDelta { ref data, .. } = *m else {
                panic!()
            };
            assert!(data.len() <= SNAP_CHUNK_BYTES + BLOCK_ELEMS * 4);
        }
        let total: usize = msgs
            .iter()
            .map(|m| match m {
                Msg::SnapshotDelta { data, .. } => data.len(),
                _ => 0,
            })
            .sum();
        assert_eq!(total, dim * 4);
    }

    #[test]
    fn delta_refresh_reconstructs_any_stale_version_bitwise() {
        use crate::coordinator::compress::GradView;
        use crate::coordinator::params::{ParamStore, BLOCK_ELEMS};
        use crate::coordinator::shard::ShardLayout;
        use crate::util::rng::Pcg64;
        // Property: from *any* stale version — including bootstrap (0) and
        // every intermediate publish — applying the chunked delta response
        // reconstructs the currently published θ bitwise. Dirty-block
        // patterns are arbitrary (seeded sparse updates), S ∈ {1, 2, 4}.
        let dim = 5 * BLOCK_ELEMS + 101;
        for &shards in &[1usize, 2, 4] {
            let layout = ShardLayout::new(dim, shards);
            for s in 0..shards {
                let slice_len = layout.range(s).len();
                let mut rng = Pcg64::new(7 + s as u64, shards as u64);
                let mut ps = ParamStore::new(
                    (0..slice_len).map(|i| (i as f32) * 0.25 - 3.0).collect(),
                    0.1,
                );
                // Replicas stuck at each version, holding its exact bytes.
                let mut replicas: Vec<(u64, Vec<f32>)> = vec![(0, vec![0.0; slice_len])];
                for _ in 0..12 {
                    let nnz = 1 + rng.below(7) as usize;
                    let mut idx: Vec<u32> =
                        (0..nnz).map(|_| rng.below(slice_len as u64) as u32).collect();
                    idx.sort_unstable();
                    idx.dedup();
                    let val: Vec<f32> = idx.iter().map(|&i| (i as f32).sin()).collect();
                    ps.apply_view(GradView::Sparse {
                        idx: &idx,
                        val: &val,
                    });
                    let snap = ps.cell().load();
                    replicas.push((snap.version, snap.theta().to_vec()));
                }
                let snap = ps.cell().load();
                for (have, stale) in replicas {
                    let mut out = stale;
                    // full_max 0 forces the delta path for every response.
                    for m in snapshot_response_msgs(s as u32, &snap, have, 0) {
                        let Msg::SnapshotDelta {
                            dtype,
                            block_elems,
                            idx,
                            lens,
                            data,
                            ..
                        } = roundtrip(&m)
                        else {
                            panic!("expected SnapshotDelta");
                        };
                        apply_snapshot_delta(dtype, block_elems, &idx, &lens, &data, &mut out)
                            .unwrap();
                    }
                    for (j, (a, b)) in out.iter().zip(snap.theta()).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "S={shards} shard={s} have={have} elem={j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn apply_snapshot_delta_rejects_bad_geometry() {
        let mut out = vec![0.0f32; 100];
        // block index past the end
        let err = apply_snapshot_delta(0, 4096, &[1], &[400], &vec![0u8; 400], &mut out);
        assert!(matches!(err, Err(WireError::Invalid(_))), "{err:?}");
        // wrong byte length for the (only, partial) block
        let err = apply_snapshot_delta(0, 4096, &[0], &[396], &vec![0u8; 396], &mut out);
        assert!(matches!(err, Err(WireError::Invalid(_))), "{err:?}");
        // truncated data
        let err = apply_snapshot_delta(0, 4096, &[0], &[400], &vec![0u8; 100], &mut out);
        assert!(matches!(err, Err(WireError::Truncated { .. })), "{err:?}");
        // unknown dtype
        let err = apply_snapshot_delta(9, 4096, &[], &[], &[], &mut out);
        assert!(matches!(err, Err(WireError::Invalid(_))), "{err:?}");
        // valid: one partial block covering the whole buffer
        let theta: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let mut data = Vec::new();
        for &x in &theta {
            data.extend_from_slice(&x.to_le_bytes());
        }
        apply_snapshot_delta(0, 4096, &[0], &[400], &data, &mut out).unwrap();
        assert_eq!(out, theta);
    }

    #[test]
    fn oversized_length_fields_are_typed_errors_not_truncation() {
        // A sparse gradient whose declared dim exceeds u32::MAX would have
        // silently encoded `dim as u32` == 0 before; now it refuses. The
        // empty idx/val keep the test allocation-free.
        let evil = ShardGrad::Sparse(Arc::new(SparseGrad {
            dim: 1usize << 33,
            idx: vec![],
            val: vec![],
        }));
        let mut buf = Vec::new();
        let err = encode_submit_into(0, 0, 0, 0.0, &evil, 0..(1usize << 33), &mut buf);
        assert!(
            matches!(err, Err(WireError::TooLong { what: "sparse shard dim", .. })),
            "{err:?}"
        );
        let err_disp = err.unwrap_err().to_string();
        assert!(err_disp.contains("u32 wire limit"), "{err_disp}");
        // Exactly u32::MAX still encodes (boundary is inclusive).
        let mut ok = Vec::new();
        assert!(put_len_u32(&mut ok, u32::MAX as usize, "x").is_ok());
        assert!(put_len_u32(&mut ok, u32::MAX as usize + 1, "x").is_err());
    }
}
