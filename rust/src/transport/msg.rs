//! The control-plane message set and its binary encoding.
//!
//! One [`Msg`] travels per frame ([`super::frame`]). All integers are
//! little-endian; floats are IEEE-754 bit patterns (NaN-safe roundtrips).
//! Gradient payloads are encoded **shard-local**: a remote worker sends each
//! shard only its slice of the submission, so full-dimension formats (dense,
//! int8) are cut down to the shard's range at encode time and decode into
//! the shard-local [`ShardGrad::DenseLocal`] / [`ShardGrad::QuantLocal`]
//! variants; sparse formats are pre-split per shard with local indices
//! already (see `coordinator::compress`), exactly like the in-process
//! protocol.
//!
//! Every malformed input decodes to a typed [`WireError`] — truncation at
//! any offset, unknown tags, out-of-range sparse indices, bad UTF-8 —
//! never a panic and never a silently wrong payload (fuzzed in
//! `tests/property_transport.rs`).

use crate::coordinator::compress::{QuantGrad, ShardGrad, SparseGrad, SparseQuantGrad};
use std::fmt;
use std::ops::Range;
use std::sync::Arc;

/// Message tags (frame payload byte 0).
const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_SUBMIT: u8 = 3;
const TAG_GRAD_ACK: u8 = 4;
const TAG_SNAP_REQ: u8 = 5;
const TAG_SNAP_SLICE: u8 = 6;
const TAG_HEARTBEAT: u8 = 7;
const TAG_SHUTDOWN: u8 = 8;
const TAG_LEAVE: u8 = 9;
const TAG_EVICT: u8 = 10;
const TAG_STATUS_REQ: u8 = 11;
const TAG_STATUS: u8 = 12;
const TAG_SUBSCRIBE: u8 = 13;
const TAG_STATUS_DELTA: u8 = 14;

/// Gradient payload tags (inside `SubmitGrad`).
const GRAD_DENSE: u8 = 0;
const GRAD_SPARSE: u8 = 1;
const GRAD_QUANT: u8 = 2;
const GRAD_SPARSE_QUANT: u8 = 3;

/// `SubmitGrad` fixed header: tag (1) + shard (4) + seq (8) +
/// base_version (8) + loss (4).
pub const SUBMIT_HEADER_BYTES: usize = 25;

/// Per-format gradient headers inside a `SubmitGrad` payload.
pub const GRAD_DENSE_HEADER_BYTES: usize = 5; // tag + n
pub const GRAD_SPARSE_HEADER_BYTES: usize = 9; // tag + dim + nnz
pub const GRAD_QUANT_HEADER_BYTES: usize = 9; // tag + n + scale
pub const GRAD_SPARSE_QUANT_HEADER_BYTES: usize = 13; // tag + dim + scale + nnz

/// Worker id in a `Hello` requesting a fresh assignment.
pub const WORKER_UNASSIGNED: u32 = u32::MAX;

/// A control-plane message. `SubmitGrad` carries a **shard-local** payload
/// (`DenseLocal` / `Sparse` / `QuantLocal` / `SparseQuant`).
#[derive(Clone, Debug)]
pub enum Msg {
    /// Client → server: join the run. `worker` is [`WORKER_UNASSIGNED`] for
    /// a first attach or a previously assigned id on reconnect; `shards` is
    /// the client's expected shard count (0 = unknown, server decides);
    /// `wire` is the worker's gradient wire format (`WireFormat` syntax),
    /// carried for telemetry/validation — decode is format-agnostic.
    Hello { worker: u32, shards: u32, wire: String },
    /// Server → client: attach accepted. Carries everything the worker
    /// needs to mirror the in-process configuration: its assigned id, the
    /// run's total worker count (data sharding), the PS shard count, the
    /// flat parameter dimension and whether this worker is in the delayed
    /// fraction (the paper's heterogeneity model assigns by id, so the
    /// server owns the draw).
    Welcome {
        worker: u32,
        workers: u32,
        shards: u32,
        dim: u64,
        delayed: bool,
    },
    /// Client → server: one shard's slice of a gradient submission. `seq`
    /// is the worker's submission counter (gap telemetry).
    SubmitGrad {
        shard: u32,
        seq: u64,
        base_version: u64,
        loss: f32,
        grad: ShardGrad,
    },
    /// Server → client: the O(1) version-token reply — the wire form of
    /// `server::Reply` (`changed = false` ⇔ `Reply::Unchanged`).
    GradAck {
        shard: u32,
        version: u64,
        changed: bool,
    },
    /// Client → server: send me shard `shard`'s parameters if newer than
    /// `version` (always answered; equal version returns the same slice).
    SnapshotRequest { shard: u32, version: u64 },
    /// Server → client: one shard's parameter slice at `version`.
    SnapshotSlice {
        shard: u32,
        version: u64,
        theta: Vec<f32>,
    },
    /// Either direction: liveness. A peer silent for longer than the
    /// heartbeat timeout is considered half-open and dropped.
    Heartbeat { seq: u64 },
    /// Server → client: the run is over; drain and exit cleanly.
    Shutdown,
    /// Client → server: clean departure of worker `worker`. Under elastic
    /// membership the server removes the worker from the barrier
    /// denominator immediately instead of waiting for the heartbeat
    /// timeout; the slot reopens for late joiners.
    Leave { worker: u32 },
    /// Server → client: this worker's slot is gone (reassigned, or the run
    /// is elastic and the worker was declared dead). Terminal: the client
    /// must not redial under the old identity — unlike the `Shutdown`
    /// refusal, which a reconnecting client retries through.
    Evict { worker: u32 },
    /// Client → server: read-only ops-plane probe — report the run's live
    /// status. Answerable before a `Hello` (a dashboard never takes a
    /// worker slot) and never touches the gradient plane.
    StatusRequest,
    /// Server → client: the status document, a UTF-8 JSON string (schema
    /// in DESIGN.md §2.9). JSON rather than fixed fields so dashboards can
    /// evolve without a wire-protocol bump.
    Status { json: String },
    /// Client → server: push-based ops plane — stream status documents at
    /// `interval_ms` (clamped server-side) instead of being polled. Like
    /// `StatusRequest`, answerable before a `Hello`; the first
    /// [`Msg::StatusDelta`] is pushed immediately on subscription.
    Subscribe { interval_ms: u32 },
    /// Server → client: one pushed status snapshot. `seq` numbers the
    /// deltas on this connection from 0, so a follower can detect gaps.
    /// The document is byte-identical to what a `StatusRequest` answered
    /// at the same instant would carry (DESIGN.md §2.11).
    StatusDelta { seq: u64, json: String },
}

/// Typed decode errors for the message layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The payload ended before the field being read.
    Truncated { need: usize, have: usize },
    /// Unknown message tag.
    UnknownMsg(u8),
    /// Unknown gradient-payload tag.
    UnknownPayload(u8),
    /// Structurally valid but semantically impossible (index out of range,
    /// inconsistent lengths, bad UTF-8, trailing garbage).
    Invalid(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated message: need {need} bytes, have {have}")
            }
            WireError::UnknownMsg(t) => write!(f, "unknown message tag {t}"),
            WireError::UnknownPayload(t) => write!(f, "unknown gradient payload tag {t}"),
            WireError::Invalid(why) => write!(f, "invalid message: {why}"),
        }
    }
}

impl std::error::Error for WireError {}

// ---- primitive writers ---------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    out.reserve(vs.len() * 4);
    for &v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_u32s(out: &mut Vec<u8>, vs: &[u32]) {
    out.reserve(vs.len() * 4);
    for &v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_i8s(out: &mut Vec<u8>, vs: &[i8]) {
    out.reserve(vs.len());
    for &v in vs {
        out.push(v as u8);
    }
}

// ---- primitive reader ----------------------------------------------------

struct Rd<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b, off: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let have = self.b.len() - self.off;
        if have < n {
            return Err(WireError::Truncated {
                need: self.off + n,
                have: self.b.len(),
            });
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        let s = self.take(4)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        let s = self.take(n.checked_mul(4).ok_or_else(|| {
            WireError::Invalid(format!("count {n} overflows"))
        })?)?;
        Ok(s.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>, WireError> {
        let s = self.take(n.checked_mul(4).ok_or_else(|| {
            WireError::Invalid(format!("count {n} overflows"))
        })?)?;
        Ok(s.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn i8s(&mut self, n: usize) -> Result<Vec<i8>, WireError> {
        let s = self.take(n)?;
        Ok(s.iter().map(|&b| b as i8).collect())
    }

    fn done(&self) -> Result<(), WireError> {
        if self.off != self.b.len() {
            return Err(WireError::Invalid(format!(
                "{} trailing bytes after message",
                self.b.len() - self.off
            )));
        }
        Ok(())
    }
}

// ---- gradient payload ----------------------------------------------------

/// Append the shard-local encoding of one shard's portion of `grad` to
/// `out`. `range` is the shard's slice of the flat θ; full-dimension
/// payloads are cut to it, shard-local payloads (pre-split sparse, or
/// payloads that already came off the wire) are written as-is.
pub fn encode_grad_into(grad: &ShardGrad, range: Range<usize>, out: &mut Vec<u8>) {
    match grad {
        ShardGrad::Dense(g) => {
            out.push(GRAD_DENSE);
            let slice = &g[range];
            put_u32(out, slice.len() as u32);
            put_f32s(out, slice);
        }
        ShardGrad::DenseLocal(g) => {
            out.push(GRAD_DENSE);
            put_u32(out, g.len() as u32);
            put_f32s(out, g);
        }
        ShardGrad::Sparse(s) => {
            out.push(GRAD_SPARSE);
            put_u32(out, s.dim as u32);
            put_u32(out, s.idx.len() as u32);
            put_u32s(out, &s.idx);
            put_f32s(out, &s.val);
        }
        ShardGrad::Quant(q) => {
            out.push(GRAD_QUANT);
            let slice = &q.data[range];
            put_u32(out, slice.len() as u32);
            put_f32(out, q.scale);
            put_i8s(out, slice);
        }
        ShardGrad::QuantLocal(q) => {
            out.push(GRAD_QUANT);
            put_u32(out, q.data.len() as u32);
            put_f32(out, q.scale);
            put_i8s(out, &q.data);
        }
        ShardGrad::SparseQuant(s) => {
            out.push(GRAD_SPARSE_QUANT);
            put_u32(out, s.dim as u32);
            put_f32(out, s.scale);
            put_u32(out, s.idx.len() as u32);
            put_u32s(out, &s.idx);
            put_i8s(out, &s.data);
        }
    }
}

/// Decode a shard-local gradient payload. Sparse indices are validated
/// against the declared dimension so a corrupt-but-CRC-colliding payload
/// can never scatter-add out of bounds.
fn decode_grad(r: &mut Rd) -> Result<ShardGrad, WireError> {
    match r.u8()? {
        GRAD_DENSE => {
            let n = r.u32()? as usize;
            Ok(ShardGrad::DenseLocal(Arc::new(r.f32s(n)?)))
        }
        GRAD_SPARSE => {
            let dim = r.u32()? as usize;
            let nnz = r.u32()? as usize;
            if nnz > dim {
                return Err(WireError::Invalid(format!(
                    "sparse nnz {nnz} exceeds shard dim {dim}"
                )));
            }
            let idx = r.u32s(nnz)?;
            let val = r.f32s(nnz)?;
            if let Some(&bad) = idx.iter().find(|&&i| i as usize >= dim) {
                return Err(WireError::Invalid(format!(
                    "sparse index {bad} out of range for shard dim {dim}"
                )));
            }
            Ok(ShardGrad::Sparse(Arc::new(SparseGrad { dim, idx, val })))
        }
        GRAD_QUANT => {
            let n = r.u32()? as usize;
            let scale = r.f32()?;
            Ok(ShardGrad::QuantLocal(Arc::new(QuantGrad {
                scale,
                data: r.i8s(n)?,
            })))
        }
        GRAD_SPARSE_QUANT => {
            let dim = r.u32()? as usize;
            let scale = r.f32()?;
            let nnz = r.u32()? as usize;
            if nnz > dim {
                return Err(WireError::Invalid(format!(
                    "sparse-quant nnz {nnz} exceeds shard dim {dim}"
                )));
            }
            let idx = r.u32s(nnz)?;
            let data = r.i8s(nnz)?;
            if let Some(&bad) = idx.iter().find(|&&i| i as usize >= dim) {
                return Err(WireError::Invalid(format!(
                    "sparse-quant index {bad} out of range for shard dim {dim}"
                )));
            }
            Ok(ShardGrad::SparseQuant(Arc::new(SparseQuantGrad {
                dim,
                idx,
                scale,
                data,
            })))
        }
        t => Err(WireError::UnknownPayload(t)),
    }
}

// ---- message encode / decode ---------------------------------------------

/// Encode a `SubmitGrad` without constructing a [`Msg`] — the worker hot
/// path. Clears and refills `out` (reused round-trip, no steady-state
/// allocation). `range` is the destination shard's slice of the flat θ.
#[allow(clippy::too_many_arguments)]
pub fn encode_submit_into(
    shard: u32,
    seq: u64,
    base_version: u64,
    loss: f32,
    grad: &ShardGrad,
    range: Range<usize>,
    out: &mut Vec<u8>,
) {
    out.clear();
    out.push(TAG_SUBMIT);
    put_u32(out, shard);
    put_u64(out, seq);
    put_u64(out, base_version);
    put_f32(out, loss);
    encode_grad_into(grad, range, out);
}

/// Encode a `SnapshotSlice` without constructing a [`Msg`] — the serving
/// hot path answers snapshot requests straight out of a cell's published
/// `Arc<ParamSnapshot>` without cloning θ. Clears and refills `out`;
/// byte-identical to `Msg::SnapshotSlice { .. }.encode_into(out)`.
pub fn encode_snapshot_slice_into(shard: u32, version: u64, theta: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.push(TAG_SNAP_SLICE);
    put_u32(out, shard);
    put_u64(out, version);
    put_u32(out, theta.len() as u32);
    put_f32s(out, theta);
}

impl Msg {
    /// Encode into `out` (cleared and refilled). For `SubmitGrad` the
    /// payload must already be shard-local (as decoded payloads are); the
    /// worker's encode path uses [`encode_submit_into`] to slice full-dim
    /// payloads without an intermediate `Msg`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        match self {
            Msg::Hello {
                worker,
                shards,
                wire,
            } => {
                out.push(TAG_HELLO);
                put_u32(out, *worker);
                put_u32(out, *shards);
                put_u32(out, wire.len() as u32);
                out.extend_from_slice(wire.as_bytes());
            }
            Msg::Welcome {
                worker,
                workers,
                shards,
                dim,
                delayed,
            } => {
                out.push(TAG_WELCOME);
                put_u32(out, *worker);
                put_u32(out, *workers);
                put_u32(out, *shards);
                put_u64(out, *dim);
                out.push(u8::from(*delayed));
            }
            Msg::SubmitGrad {
                shard,
                seq,
                base_version,
                loss,
                grad,
            } => {
                out.push(TAG_SUBMIT);
                put_u32(out, *shard);
                put_u64(out, *seq);
                put_u64(out, *base_version);
                put_f32(out, *loss);
                // Payload is shard-local by contract: encode its full
                // extent. The range end is not used for local variants.
                let len = match grad {
                    ShardGrad::Dense(g) => g.len(),
                    ShardGrad::DenseLocal(g) => g.len(),
                    ShardGrad::Quant(q) => q.data.len(),
                    ShardGrad::QuantLocal(q) => q.data.len(),
                    ShardGrad::Sparse(s) => s.dim,
                    ShardGrad::SparseQuant(s) => s.dim,
                };
                encode_grad_into(grad, 0..len, out);
            }
            Msg::GradAck {
                shard,
                version,
                changed,
            } => {
                out.push(TAG_GRAD_ACK);
                put_u32(out, *shard);
                put_u64(out, *version);
                out.push(u8::from(*changed));
            }
            Msg::SnapshotRequest { shard, version } => {
                out.push(TAG_SNAP_REQ);
                put_u32(out, *shard);
                put_u64(out, *version);
            }
            Msg::SnapshotSlice {
                shard,
                version,
                theta,
            } => {
                out.push(TAG_SNAP_SLICE);
                put_u32(out, *shard);
                put_u64(out, *version);
                put_u32(out, theta.len() as u32);
                put_f32s(out, theta);
            }
            Msg::Heartbeat { seq } => {
                out.push(TAG_HEARTBEAT);
                put_u64(out, *seq);
            }
            Msg::Shutdown => out.push(TAG_SHUTDOWN),
            Msg::Leave { worker } => {
                out.push(TAG_LEAVE);
                put_u32(out, *worker);
            }
            Msg::Evict { worker } => {
                out.push(TAG_EVICT);
                put_u32(out, *worker);
            }
            Msg::StatusRequest => out.push(TAG_STATUS_REQ),
            Msg::Status { json } => {
                out.push(TAG_STATUS);
                put_u32(out, json.len() as u32);
                out.extend_from_slice(json.as_bytes());
            }
            Msg::Subscribe { interval_ms } => {
                out.push(TAG_SUBSCRIBE);
                put_u32(out, *interval_ms);
            }
            Msg::StatusDelta { seq, json } => {
                out.push(TAG_STATUS_DELTA);
                put_u64(out, *seq);
                put_u32(out, json.len() as u32);
                out.extend_from_slice(json.as_bytes());
            }
        }
    }

    /// Decode one message from a frame payload. Rejects trailing garbage
    /// (a frame carries exactly one message).
    pub fn decode(buf: &[u8]) -> Result<Msg, WireError> {
        let mut r = Rd::new(buf);
        let msg = match r.u8()? {
            TAG_HELLO => {
                let worker = r.u32()?;
                let shards = r.u32()?;
                let n = r.u32()? as usize;
                let wire = std::str::from_utf8(r.take(n)?)
                    .map_err(|_| WireError::Invalid("hello wire format is not UTF-8".into()))?
                    .to_string();
                Msg::Hello {
                    worker,
                    shards,
                    wire,
                }
            }
            TAG_WELCOME => Msg::Welcome {
                worker: r.u32()?,
                workers: r.u32()?,
                shards: r.u32()?,
                dim: r.u64()?,
                delayed: r.u8()? != 0,
            },
            TAG_SUBMIT => Msg::SubmitGrad {
                shard: r.u32()?,
                seq: r.u64()?,
                base_version: r.u64()?,
                loss: r.f32()?,
                grad: decode_grad(&mut r)?,
            },
            TAG_GRAD_ACK => Msg::GradAck {
                shard: r.u32()?,
                version: r.u64()?,
                changed: r.u8()? != 0,
            },
            TAG_SNAP_REQ => Msg::SnapshotRequest {
                shard: r.u32()?,
                version: r.u64()?,
            },
            TAG_SNAP_SLICE => {
                let shard = r.u32()?;
                let version = r.u64()?;
                let n = r.u32()? as usize;
                Msg::SnapshotSlice {
                    shard,
                    version,
                    theta: r.f32s(n)?,
                }
            }
            TAG_HEARTBEAT => Msg::Heartbeat { seq: r.u64()? },
            TAG_SHUTDOWN => Msg::Shutdown,
            TAG_LEAVE => Msg::Leave { worker: r.u32()? },
            TAG_EVICT => Msg::Evict { worker: r.u32()? },
            TAG_STATUS_REQ => Msg::StatusRequest,
            TAG_STATUS => {
                let n = r.u32()? as usize;
                let json = std::str::from_utf8(r.take(n)?)
                    .map_err(|_| WireError::Invalid("status document is not UTF-8".into()))?
                    .to_string();
                Msg::Status { json }
            }
            TAG_SUBSCRIBE => Msg::Subscribe {
                interval_ms: r.u32()?,
            },
            TAG_STATUS_DELTA => {
                let seq = r.u64()?;
                let n = r.u32()? as usize;
                let json = std::str::from_utf8(r.take(n)?)
                    .map_err(|_| WireError::Invalid("status delta is not UTF-8".into()))?
                    .to_string();
                Msg::StatusDelta { seq, json }
            }
            t => return Err(WireError::UnknownMsg(t)),
        };
        r.done()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Msg) -> Msg {
        let mut buf = Vec::new();
        msg.encode_into(&mut buf);
        Msg::decode(&buf).expect("roundtrip decode")
    }

    #[test]
    fn control_messages_roundtrip_exhaustively() {
        // Hello
        let m = roundtrip(&Msg::Hello {
            worker: WORKER_UNASSIGNED,
            shards: 4,
            wire: "topk:0.01".into(),
        });
        match m {
            Msg::Hello {
                worker,
                shards,
                wire,
            } => {
                assert_eq!(worker, WORKER_UNASSIGNED);
                assert_eq!(shards, 4);
                assert_eq!(wire, "topk:0.01");
            }
            other => panic!("{other:?}"),
        }
        // Welcome
        let m = roundtrip(&Msg::Welcome {
            worker: 3,
            workers: 8,
            shards: 2,
            dim: 111_936,
            delayed: true,
        });
        match m {
            Msg::Welcome {
                worker,
                workers,
                shards,
                dim,
                delayed,
            } => {
                assert_eq!((worker, workers, shards, dim, delayed), (3, 8, 2, 111_936, true));
            }
            other => panic!("{other:?}"),
        }
        // GradAck
        let m = roundtrip(&Msg::GradAck {
            shard: 1,
            version: 42,
            changed: false,
        });
        match m {
            Msg::GradAck {
                shard,
                version,
                changed,
            } => assert_eq!((shard, version, changed), (1, 42, false)),
            other => panic!("{other:?}"),
        }
        // SnapshotRequest
        let m = roundtrip(&Msg::SnapshotRequest {
            shard: 7,
            version: u64::MAX,
        });
        match m {
            Msg::SnapshotRequest { shard, version } => {
                assert_eq!((shard, version), (7, u64::MAX))
            }
            other => panic!("{other:?}"),
        }
        // SnapshotSlice (with a NaN: bit-exact float transport)
        let theta = vec![1.5f32, -0.0, f32::NAN, f32::MIN_POSITIVE];
        let m = roundtrip(&Msg::SnapshotSlice {
            shard: 0,
            version: 9,
            theta: theta.clone(),
        });
        match m {
            Msg::SnapshotSlice {
                shard,
                version,
                theta: got,
            } => {
                assert_eq!((shard, version), (0, 9));
                assert_eq!(got.len(), theta.len());
                for (a, b) in got.iter().zip(&theta) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
        // Heartbeat + Shutdown
        assert!(matches!(
            roundtrip(&Msg::Heartbeat { seq: 12345 }),
            Msg::Heartbeat { seq: 12345 }
        ));
        assert!(matches!(roundtrip(&Msg::Shutdown), Msg::Shutdown));
        // Leave + Evict (elastic membership control plane)
        assert!(matches!(
            roundtrip(&Msg::Leave { worker: 6 }),
            Msg::Leave { worker: 6 }
        ));
        assert!(matches!(
            roundtrip(&Msg::Evict { worker: 2 }),
            Msg::Evict { worker: 2 }
        ));
        // truncated membership messages are typed errors, not panics
        let mut buf = Vec::new();
        Msg::Leave { worker: 6 }.encode_into(&mut buf);
        assert!(matches!(
            Msg::decode(&buf[..3]),
            Err(WireError::Truncated { .. })
        ));
        // StatusRequest + Status (the read-only ops plane)
        assert!(matches!(roundtrip(&Msg::StatusRequest), Msg::StatusRequest));
        let doc = r#"{"workers":{"active":3},"shards":[{"k":2}]}"#;
        match roundtrip(&Msg::Status { json: doc.into() }) {
            Msg::Status { json } => assert_eq!(json, doc),
            other => panic!("{other:?}"),
        }
        // non-empty unicode survives (the doc may carry escaped keys)
        match roundtrip(&Msg::Status { json: "{\"é\":1}".into() }) {
            Msg::Status { json } => assert_eq!(json, "{\"é\":1}"),
            other => panic!("{other:?}"),
        }
        // truncated status documents are typed errors, not panics
        let mut buf = Vec::new();
        Msg::Status { json: doc.into() }.encode_into(&mut buf);
        for cut in [1, 4, buf.len() - 1] {
            assert!(matches!(
                Msg::decode(&buf[..cut]),
                Err(WireError::Truncated { .. })
            ));
        }
        // trailing garbage after a StatusRequest is rejected
        let mut sr = Vec::new();
        Msg::StatusRequest.encode_into(&mut sr);
        sr.push(7);
        assert!(matches!(Msg::decode(&sr), Err(WireError::Invalid(_))));
    }

    #[test]
    fn subscription_messages_roundtrip_and_reject_malformed_frames() {
        // Subscribe carries the requested push interval verbatim.
        for interval_ms in [0u32, 1, 250, u32::MAX] {
            match roundtrip(&Msg::Subscribe { interval_ms }) {
                Msg::Subscribe { interval_ms: i } => assert_eq!(i, interval_ms),
                other => panic!("{other:?}"),
            }
        }
        // StatusDelta: sequence number + the pushed document.
        let doc = r#"{"workers":{"active":2},"stages":{"apply":{"count":7}}}"#;
        match roundtrip(&Msg::StatusDelta { seq: 41, json: doc.into() }) {
            Msg::StatusDelta { seq, json } => {
                assert_eq!(seq, 41);
                assert_eq!(json, doc);
            }
            other => panic!("{other:?}"),
        }
        // Truncations anywhere in the frame are typed errors, not panics.
        let mut buf = Vec::new();
        Msg::StatusDelta { seq: 7, json: doc.into() }.encode_into(&mut buf);
        for cut in [1, 5, 9, 12, buf.len() - 1] {
            assert!(matches!(
                Msg::decode(&buf[..cut]),
                Err(WireError::Truncated { .. })
            ));
        }
        // A delta whose payload is not UTF-8 is rejected as Invalid.
        let mut bad = Vec::new();
        bad.push(TAG_STATUS_DELTA);
        put_u64(&mut bad, 0);
        put_u32(&mut bad, 2);
        bad.extend_from_slice(&[0xFF, 0xFE]);
        assert!(matches!(Msg::decode(&bad), Err(WireError::Invalid(_))));
        // Trailing garbage after a Subscribe is rejected.
        let mut sub = Vec::new();
        Msg::Subscribe { interval_ms: 100 }.encode_into(&mut sub);
        sub.push(0);
        assert!(matches!(Msg::decode(&sub), Err(WireError::Invalid(_))));
    }

    #[test]
    fn submit_roundtrips_every_payload_kind() {
        let dense = ShardGrad::Dense(Arc::new(vec![1.0f32, -2.0, 3.0, 0.5]));
        let sparse = ShardGrad::Sparse(Arc::new(SparseGrad {
            dim: 4,
            idx: vec![0, 3],
            val: vec![0.25, -0.75],
        }));
        let quant = ShardGrad::Quant(Arc::new(QuantGrad {
            scale: 0.5,
            data: vec![1, -1, 127, -127],
        }));
        let sq = ShardGrad::SparseQuant(Arc::new(SparseQuantGrad {
            dim: 4,
            idx: vec![1, 2],
            scale: 0.25,
            data: vec![-4, 8],
        }));
        for (grad, range) in [
            (dense, 1..3usize), // full-dim payload: only the slice travels
            (sparse, 0..4),
            (quant, 1..3),
            (sq, 0..4),
        ] {
            let mut buf = Vec::new();
            encode_submit_into(2, 77, 5, 0.125, &grad, range.clone(), &mut buf);
            let msg = Msg::decode(&buf).unwrap();
            let Msg::SubmitGrad {
                shard,
                seq,
                base_version,
                loss,
                grad: got,
            } = msg
            else {
                panic!("expected SubmitGrad");
            };
            assert_eq!((shard, seq, base_version), (2, 77, 5));
            assert_eq!(loss, 0.125);
            // The decoded (shard-local) payload views identically to the
            // original sliced to the shard's range.
            let shard_len = range.len();
            let mut want = vec![0.0f32; shard_len];
            grad.view(range).add_to(&mut want);
            let mut have = vec![0.0f32; shard_len];
            got.view(0..shard_len).add_to(&mut have);
            for (a, b) in want.iter().zip(&have) {
                assert_eq!(a.to_bits(), b.to_bits(), "{grad:?}");
            }
            // byte accounting survives the trip
            assert_eq!(grad.wire_bytes(shard_len), got.wire_bytes(shard_len));
            // re-encoding the decoded (local) payload is byte-identical
            let mut again = Vec::new();
            encode_submit_into(2, 77, 5, 0.125, &got, 0..shard_len, &mut again);
            assert_eq!(buf, again);
        }
    }

    #[test]
    fn decode_rejects_unknown_tags_and_garbage() {
        assert!(matches!(
            Msg::decode(&[99]),
            Err(WireError::UnknownMsg(99))
        ));
        // unknown gradient payload tag inside a submit
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.0,
            &ShardGrad::DenseLocal(Arc::new(vec![1.0])),
            0..1,
            &mut buf,
        );
        buf[SUBMIT_HEADER_BYTES] = 200;
        assert!(matches!(
            Msg::decode(&buf),
            Err(WireError::UnknownPayload(200))
        ));
        // trailing garbage after a well-formed message
        let mut hb = Vec::new();
        Msg::Heartbeat { seq: 1 }.encode_into(&mut hb);
        hb.push(0);
        assert!(matches!(Msg::decode(&hb), Err(WireError::Invalid(_))));
        // empty payload
        assert!(matches!(
            Msg::decode(&[]),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn sparse_indices_are_range_checked() {
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            1,
            0,
            0.0,
            &ShardGrad::Sparse(Arc::new(SparseGrad {
                dim: 4,
                idx: vec![3],
                val: vec![1.0],
            })),
            0..4,
            &mut buf,
        );
        // Patch the index to 4 (== dim, out of range). Layout after the
        // submit + sparse headers: idx array first.
        let idx_off = SUBMIT_HEADER_BYTES + GRAD_SPARSE_HEADER_BYTES;
        buf[idx_off..idx_off + 4].copy_from_slice(&4u32.to_le_bytes());
        match Msg::decode(&buf) {
            Err(WireError::Invalid(why)) => assert!(why.contains("out of range"), "{why}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
        // nnz > dim is rejected before reading the arrays
        let mut buf2 = Vec::new();
        encode_submit_into(
            0,
            1,
            0,
            0.0,
            &ShardGrad::Sparse(Arc::new(SparseGrad {
                dim: 2,
                idx: vec![0, 1],
                val: vec![1.0, 2.0],
            })),
            0..2,
            &mut buf2,
        );
        let nnz_off = SUBMIT_HEADER_BYTES + 5; // tag + dim
        buf2[nnz_off..nnz_off + 4].copy_from_slice(&3u32.to_le_bytes());
        assert!(matches!(Msg::decode(&buf2), Err(WireError::Invalid(_))));
    }

    #[test]
    fn header_byte_constants_match_the_encoder() {
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.0,
            &ShardGrad::DenseLocal(Arc::new(vec![0.0; 10])),
            0..10,
            &mut buf,
        );
        assert_eq!(buf.len(), SUBMIT_HEADER_BYTES + GRAD_DENSE_HEADER_BYTES + 40);
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.0,
            &ShardGrad::Sparse(Arc::new(SparseGrad {
                dim: 10,
                idx: vec![1, 2, 3],
                val: vec![0.0; 3],
            })),
            0..10,
            &mut buf,
        );
        assert_eq!(
            buf.len(),
            SUBMIT_HEADER_BYTES + GRAD_SPARSE_HEADER_BYTES + 3 * 8
        );
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.0,
            &ShardGrad::QuantLocal(Arc::new(QuantGrad {
                scale: 1.0,
                data: vec![0; 10],
            })),
            0..10,
            &mut buf,
        );
        assert_eq!(buf.len(), SUBMIT_HEADER_BYTES + GRAD_QUANT_HEADER_BYTES + 10);
        let mut buf = Vec::new();
        encode_submit_into(
            0,
            0,
            0,
            0.0,
            &ShardGrad::SparseQuant(Arc::new(SparseQuantGrad {
                dim: 10,
                idx: vec![1, 2],
                scale: 1.0,
                data: vec![0, 0],
            })),
            0..10,
            &mut buf,
        );
        assert_eq!(
            buf.len(),
            SUBMIT_HEADER_BYTES + GRAD_SPARSE_QUANT_HEADER_BYTES + 2 * 5
        );
    }
}
