//! Populate the committed benchmark baselines from a quick-budget run in
//! the tier-1 environment.
//!
//! The authoring container has no Rust toolchain, so `BENCH_compress.json`,
//! `BENCH_transport.json`, `BENCH_trace.json` and `BENCH_memory.json` ship
//! with exact byte counts but `ops_per_sec: null`. The tier-1 suite is the first place the code
//! actually runs; this test re-measures each case with a small fixed
//! budget and writes the numbers into the baseline files (only filling
//! nulls — a populated file is left alone except for a consistency check
//! of the hardware-independent byte columns). The build profile is
//! recorded alongside (`cargo test` is usually a debug build; full-budget
//! release numbers come from `BENCH_COMPRESS_OUT` / `BENCH_TRANSPORT_OUT`
//! bench runs, see each file's note).
//!
//! The test never fails the suite for environmental reasons: an unwritable
//! or missing baseline file degrades to a printed notice.

use hybrid_sgd::coordinator::buffer::GradientBuffer;
use hybrid_sgd::coordinator::compress::{
    dequantize_i8, quantize_i8_into, GradView, QuantGrad, ShardGrad, SparseGrad, TopKCompressor,
};
use hybrid_sgd::transport::frame::{decode_frame, encode_frame_into};
use hybrid_sgd::transport::loadgen::measure_conn_throughput;
use hybrid_sgd::transport::msg::{encode_submit_into, Msg};
use hybrid_sgd::transport::FrontendKind;
use hybrid_sgd::util::json::{parse, Json};
use hybrid_sgd::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Quick-budget ops/sec of one operation: one warm-up call, then at least
/// 3 and at most 10k timed iterations within ~25 ms.
fn measure<F: FnMut()>(mut f: F) -> f64 {
    f();
    let budget = Duration::from_millis(25);
    let start = Instant::now();
    let mut iters = 0u64;
    while (start.elapsed() < budget || iters < 3) && iters < 10_000 {
        f();
        iters += 1;
    }
    iters as f64 / start.elapsed().as_secs_f64().max(1e-9)
}

/// The `BENCH_compress.json` case set, measured exactly as
/// `bench_hotpath`'s wire-format section defines it (key = (name, dim)).
fn measure_compress_cases() -> BTreeMap<(String, usize), f64> {
    let mut out = BTreeMap::new();
    for &dim in &[10_000usize, 100_000, 1_000_000] {
        let mut rng = Pcg64::seeded(7);
        let mut grad = vec![0.0f32; dim];
        rng.fill_normal(&mut grad, 1.0);
        let k = dim / 100;

        let mut buf = GradientBuffer::new(dim, 8);
        let ops = measure(|| {
            buf.push(&grad, 0, 0, 0);
            if buf.len() >= 64 {
                buf.clear();
            }
        });
        out.insert(("dense_accumulate".to_string(), dim), ops);

        let mut comp = TopKCompressor::new(dim, k);
        let mut sg = SparseGrad::with_dim(dim);
        let ops = measure(|| comp.compress_into(&grad, &mut sg));
        out.insert(("topk1pct_compress".to_string(), dim), ops);

        let mut buf2 = GradientBuffer::new(dim, 8);
        let ops = measure(|| {
            buf2.push_view(
                GradView::Sparse {
                    idx: &sg.idx,
                    val: &sg.val,
                },
                0,
                0,
                0,
            );
            if buf2.len() >= 64 {
                buf2.clear();
            }
        });
        out.insert(("topk1pct_accumulate".to_string(), dim), ops);

        let mut q = QuantGrad::empty();
        let ops = measure(|| quantize_i8_into(&grad, &mut q));
        out.insert(("int8_quantize".to_string(), dim), ops);

        let mut buf3 = GradientBuffer::new(dim, 8);
        let ops = measure(|| {
            buf3.push_view(
                GradView::Quant {
                    scale: q.scale,
                    data: &q.data,
                },
                0,
                0,
                0,
            );
            if buf3.len() >= 64 {
                buf3.clear();
            }
        });
        out.insert(("int8_accumulate".to_string(), dim), ops);

        let ops = measure(|| {
            std::hint::black_box(dequantize_i8(&q));
        });
        out.insert(("int8_dequantize".to_string(), dim), ops);
    }
    out
}

/// The `BENCH_transport.json` case set (key = (name, payload label)),
/// mirroring `bench_hotpath`'s transport section. Returns ops/sec plus the
/// exact frame size for the byte-column consistency check.
fn measure_transport_cases() -> BTreeMap<(String, String), (f64, usize)> {
    let mut out = BTreeMap::new();
    let sizes: [(&str, usize, usize, usize); 4] = [
        ("800B", 200, 100, 800),
        ("8KB", 2_000, 1_000, 8_000),
        ("80KB", 20_000, 10_000, 80_000),
        ("4MB", 1_000_000, 500_000, 4_000_000),
    ];
    let mut rng = Pcg64::seeded(31);
    for (label, dense_n, nnz, int8_n) in sizes {
        let mut dense = vec![0.0f32; dense_n];
        rng.fill_normal(&mut dense, 1.0);
        let sparse = SparseGrad {
            dim: nnz * 2,
            idx: (0..nnz as u32).map(|i| i * 2).collect(),
            val: {
                let mut v = vec![0.0f32; nnz];
                rng.fill_normal(&mut v, 1.0);
                v
            },
        };
        let quant = QuantGrad {
            scale: 0.01,
            data: (0..int8_n).map(|i| (i % 251) as i8).collect(),
        };
        let payloads: [(&str, ShardGrad, usize); 3] = [
            ("dense", ShardGrad::Dense(Arc::new(dense)), dense_n),
            ("topk", ShardGrad::Sparse(Arc::new(sparse)), nnz * 2),
            ("int8", ShardGrad::Quant(Arc::new(quant)), int8_n),
        ];
        for (fmt, grad, shard_len) in payloads {
            let mut msg_buf = Vec::new();
            let mut frame_buf = Vec::new();
            encode_submit_into(0, 1, 2, 0.5, &grad, 0..shard_len, &mut msg_buf).unwrap();
            frame_buf.clear();
            encode_frame_into(&msg_buf, &mut frame_buf);
            let frame_bytes = frame_buf.len();
            let ops = measure(|| {
                encode_submit_into(0, 1, 2, 0.5, &grad, 0..shard_len, &mut msg_buf).unwrap();
                frame_buf.clear();
                encode_frame_into(&msg_buf, &mut frame_buf);
            });
            out.insert(
                (format!("encode_{fmt}"), label.to_string()),
                (ops, frame_bytes),
            );
            let ops = measure(|| {
                let (payload, _) = decode_frame(&frame_buf).expect("valid frame");
                std::hint::black_box(Msg::decode(payload).expect("valid message"));
            });
            out.insert(
                (format!("decode_{fmt}"), label.to_string()),
                (ops, frame_bytes),
            );
        }
    }
    out
}

/// The `BENCH_trace.json` case set (key = name), mirroring
/// `bench_hotpath`'s tracing-overhead section: the per-arrival submit
/// sequence under each tracing configuration.
fn measure_trace_cases() -> BTreeMap<String, f64> {
    use hybrid_sgd::coordinator::params::ParamStore;
    use hybrid_sgd::coordinator::{Aggregator, Policy};
    use hybrid_sgd::util::trace::{chrome_trace_json, Stage, TraceRing};
    use std::sync::atomic::{AtomicBool, Ordering};

    let dim = 52_138;
    let mut rng = Pcg64::seeded(9);
    let mut grad = vec![0.0f32; dim];
    rng.fill_normal(&mut grad, 1.0);
    let mut out = BTreeMap::new();

    {
        let mut ps = ParamStore::new(vec![0.1; dim], 0.01);
        let mut agg = Aggregator::new(Policy::Async, dim, 8);
        let mut w = 0usize;
        let ops = measure(|| {
            let v = ps.version();
            agg.on_gradient(&mut ps, &grad, w % 8, v, 1.0);
            w += 1;
        });
        out.insert("submit_plain".to_string(), ops);
    }

    let mut traced = |trace: Option<Arc<TraceRing>>| {
        let mut ps = ParamStore::new(vec![0.1; dim], 0.01);
        let mut agg = Aggregator::new(Policy::Async, dim, 8);
        let mut w = 0usize;
        let mut seq = 0u64;
        measure(|| {
            let enq = trace.as_ref().map_or(0, |tr| tr.real_now());
            let v = ps.version();
            agg.on_gradient(&mut ps, &grad, w % 8, v, 1.0);
            if let Some(tr) = &trace {
                let now = tr.real_now();
                tr.span(Stage::Queue, (w % 8) as u32, 0, enq, now, seq, 0);
                tr.span(Stage::Apply, (w % 8) as u32, 0, now, tr.real_now(), seq, 0);
            }
            w += 1;
            seq += 1;
        })
    };
    let off = traced(None);
    let ring = traced(Some(Arc::new(TraceRing::new(1 << 16))));
    let export_ring = Arc::new(TraceRing::new(1 << 16));
    let stop = Arc::new(AtomicBool::new(false));
    let drainer = {
        let ring = Arc::clone(&export_ring);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut bytes = 0usize;
            while !stop.load(Ordering::Relaxed) {
                bytes += chrome_trace_json(&ring.drain()).len();
            }
            bytes
        })
    };
    let exporting = traced(Some(export_ring));
    stop.store(true, Ordering::Relaxed);
    std::hint::black_box(drainer.join().unwrap());

    out.insert("submit_trace_off".to_string(), off);
    out.insert("submit_trace_ring".to_string(), ring);
    out.insert("submit_trace_export".to_string(), exporting);
    out
}

/// The `BENCH_memory.json` case set (key = (name, dim, dtype)), mirroring
/// `bench_hotpath`'s memory section at the quick dims. Returns ops/sec
/// plus the exact steady-state bytes-per-publish for the byte-column
/// consistency check. The `peak_rss` rows are deliberately left alone:
/// VmHWM in a shared debug test process says nothing about the bench's
/// memory story (see the file's note).
fn measure_memory_cases() -> BTreeMap<(String, usize, String), (f64, usize)> {
    use hybrid_sgd::coordinator::params::{block_count, ParamStore, BLOCK_ELEMS};
    use hybrid_sgd::coordinator::{ParamDtype, SnapshotCell};
    let mut out = BTreeMap::new();
    for &dim in &[1_000_000usize, 10_000_000] {
        let touched = (block_count(dim) / 100).max(1);
        let idx: Vec<u32> = (0..touched as u32).map(|i| i * 100 * BLOCK_ELEMS as u32).collect();
        let val = vec![1e-3f32; touched];
        let mut grad = vec![0.0f32; dim];
        Pcg64::seeded(11).fill_normal(&mut grad, 1.0);
        for dtype in [ParamDtype::F32, ParamDtype::F16] {
            // Empty initial cell: same construction shape as the bench.
            let cell = Arc::new(SnapshotCell::new(Vec::new()));
            let mut ps = ParamStore::with_cell_dtype(vec![0.1; dim], 0.01, cell, dtype);
            let ops = measure(|| ps.apply_single(&grad));
            let (p0, b0) = (ps.publishes(), ps.snapshot_bytes_published());
            for _ in 0..4 {
                ps.apply_single(&grad);
            }
            let per = ((ps.snapshot_bytes_published() - b0) / (ps.publishes() - p0)) as usize;
            out.insert(
                ("publish_dense".to_string(), dim, dtype.as_str().to_string()),
                (ops, per),
            );

            let cell = Arc::new(SnapshotCell::new(Vec::new()));
            let mut ps = ParamStore::with_cell_dtype(vec![0.1; dim], 0.01, cell, dtype);
            let ops = measure(|| {
                ps.apply_view(GradView::Sparse {
                    idx: &idx,
                    val: &val,
                })
            });
            let (p0, b0) = (ps.publishes(), ps.snapshot_bytes_published());
            for _ in 0..4 {
                ps.apply_view(GradView::Sparse {
                    idx: &idx,
                    val: &val,
                });
            }
            let per = ((ps.snapshot_bytes_published() - b0) / (ps.publishes() - p0)) as usize;
            out.insert(
                (
                    "publish_delta1pct".to_string(),
                    dim,
                    dtype.as_str().to_string(),
                ),
                (ops, per),
            );
        }
    }
    out
}

/// Fill `ops_per_sec: null` entries of one baseline file. `key_of` maps a
/// case object to the lookup key; `lookup` returns (ops, expected bytes or
/// None to skip the byte check; byte column name differs per file).
fn populate(
    path: &std::path::Path,
    bytes_key: &str,
    resolve: impl Fn(&Json) -> Option<(f64, Option<usize>)>,
) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            println!("bench_baselines: skipping {}: {e}", path.display());
            return;
        }
    };
    let mut doc = match parse(&text) {
        Ok(d) => d,
        Err(e) => {
            println!("bench_baselines: {} does not parse: {e:#}", path.display());
            return;
        }
    };
    let Some(cases) = doc.get("cases").and_then(|c| c.as_arr()).map(|a| a.to_vec()) else {
        println!("bench_baselines: {} has no cases array", path.display());
        return;
    };
    let mut filled = 0usize;
    let mut updated_cases = Vec::with_capacity(cases.len());
    for case in cases {
        let mut obj = match case.as_obj() {
            Some(m) => m.clone(),
            None => {
                updated_cases.push(case);
                continue;
            }
        };
        if let Some((ops, bytes)) = resolve(&case) {
            let is_null = matches!(obj.get("ops_per_sec"), Some(Json::Null) | None);
            if is_null {
                obj.insert("ops_per_sec".to_string(), Json::Num(ops));
                filled += 1;
            }
            // The byte columns are exact and hardware-independent: keep
            // them honest against the code that defines them.
            if let Some(b) = bytes {
                let recorded = obj.get(bytes_key).and_then(|v| v.as_f64());
                assert_eq!(
                    recorded,
                    Some(b as f64),
                    "{}: {bytes_key} drifted from the codec for {:?}",
                    path.display(),
                    obj.get("name")
                );
            }
        }
        updated_cases.push(Json::Obj(obj));
    }
    if filled == 0 {
        println!(
            "bench_baselines: {} already fully populated",
            path.display()
        );
        return;
    }
    doc.set("cases", Json::Arr(updated_cases));
    doc.set(
        "measured_profile",
        Json::Str(if cfg!(debug_assertions) { "debug" } else { "release" }.to_string()),
    );
    doc.set(
        "measured_by",
        Json::Str("tests/bench_baselines.rs quick budget (~25 ms/case)".to_string()),
    );
    match std::fs::write(path, doc.to_string_pretty()) {
        Ok(()) => println!(
            "bench_baselines: populated {filled} ops_per_sec entries in {}",
            path.display()
        ),
        Err(e) => println!(
            "bench_baselines: could not write {}: {e} (measurements discarded)",
            path.display()
        ),
    }
}

/// Fill null rows of `BENCH_transport.json`'s `connections_vs_throughput`
/// section: a quick-budget (~100 ms/row) run of the loadgen harness for
/// each (frontend, connection-count) pair that has no measurement yet.
/// Separate from `populate` because the rows live outside the `cases`
/// array and need two fields filled. Same degradation contract:
/// environmental problems print, they never fail the suite.
fn populate_connections(path: &std::path::Path) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            println!("bench_baselines: skipping {}: {e}", path.display());
            return;
        }
    };
    let mut doc = match parse(&text) {
        Ok(d) => d,
        Err(e) => {
            println!("bench_baselines: {} does not parse: {e:#}", path.display());
            return;
        }
    };
    let Some(rows) = doc
        .get("connections_vs_throughput")
        .and_then(|c| c.as_arr())
        .map(|a| a.to_vec())
    else {
        println!(
            "bench_baselines: {} has no connections_vs_throughput section",
            path.display()
        );
        return;
    };
    let mut filled = 0usize;
    let mut updated = Vec::with_capacity(rows.len());
    for row in rows {
        let mut obj = match row.as_obj() {
            Some(m) => m.clone(),
            None => {
                updated.push(row);
                continue;
            }
        };
        let is_null = matches!(obj.get("ops_per_sec"), Some(Json::Null) | None);
        let kind = match obj.get("frontend").and_then(|v| v.as_str()) {
            Some("reactor") => Some(FrontendKind::Reactor),
            Some("threaded") => Some(FrontendKind::Threaded),
            _ => None,
        };
        let conns = obj.get("conns").and_then(|v| v.as_usize());
        if let (true, Some(kind), Some(conns)) = (is_null, kind, conns) {
            match measure_conn_throughput(kind, conns, 8, 64, Duration::from_millis(100)) {
                Ok(r) => {
                    obj.insert("ops_per_sec".to_string(), Json::Num(r.ops_per_sec));
                    obj.insert(
                        "p99_ack_latency_us".to_string(),
                        Json::Num(r.p99_ack_latency_us),
                    );
                    filled += 1;
                }
                Err(e) => println!(
                    "bench_baselines: connections row ({kind:?}, {conns}) skipped: {e}"
                ),
            }
        }
        updated.push(Json::Obj(obj));
    }
    if filled == 0 {
        println!(
            "bench_baselines: {} connections_vs_throughput already populated",
            path.display()
        );
        return;
    }
    doc.set("connections_vs_throughput", Json::Arr(updated));
    doc.set(
        "measured_profile",
        Json::Str(if cfg!(debug_assertions) { "debug" } else { "release" }.to_string()),
    );
    doc.set(
        "measured_by",
        Json::Str("tests/bench_baselines.rs quick budget (~25 ms/case)".to_string()),
    );
    match std::fs::write(path, doc.to_string_pretty()) {
        Ok(()) => println!(
            "bench_baselines: populated {filled} connections_vs_throughput rows in {}",
            path.display()
        ),
        Err(e) => println!(
            "bench_baselines: could not write {}: {e} (measurements discarded)",
            path.display()
        ),
    }
}

#[test]
fn populate_bench_baselines_from_quick_run() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");

    let compress = measure_compress_cases();
    populate(&root.join("BENCH_compress.json"), "bytes_per_step", |case| {
        let name = case.get("name")?.as_str()?.to_string();
        let dim = case.get("dim")?.as_usize()?;
        let ops = *compress.get(&(name, dim))?;
        // bytes_per_step is pinned by bench_hotpath's own assert; no
        // recomputation here.
        Some((ops, None))
    });

    let transport = measure_transport_cases();
    populate(
        &root.join("BENCH_transport.json"),
        "bytes_per_frame",
        |case| {
            let name = case.get("name")?.as_str()?.to_string();
            let payload = case.get("payload")?.as_str()?.to_string();
            let (ops, bytes) = *transport.get(&(name, payload))?;
            Some((ops, Some(bytes)))
        },
    );

    // The serving-frontend scaling rows (ISSUE 6) live outside `cases`.
    populate_connections(&root.join("BENCH_transport.json"));

    // The tracing-overhead rows (ISSUE 9). `dim` is exact and pinned by
    // the bench itself; only ops_per_sec is measured here.
    let trace = measure_trace_cases();
    populate(&root.join("BENCH_trace.json"), "dim", |case| {
        let name = case.get("name")?.as_str()?.to_string();
        let ops = *trace.get(&name)?;
        Some((ops, None))
    });

    // The big-model memory-path rows (ISSUE 10). bytes_per_publish is
    // exact steady-state accounting; keep the committed column honest.
    // Cases outside the quick dims (the full-run 1e8 row) stay null here.
    let memory = measure_memory_cases();
    populate(
        &root.join("BENCH_memory.json"),
        "bytes_per_publish",
        |case| {
            let name = case.get("name")?.as_str()?.to_string();
            let dim = case.get("dim")?.as_usize()?;
            let dtype = case.get("dtype")?.as_str()?.to_string();
            let (ops, bytes) = *memory.get(&(name, dim, dtype))?;
            Some((ops, Some(bytes)))
        },
    );
}
