//! Multi-process training over TCP: the transport subsystem end to end.
//!
//! Three layers of evidence:
//! 1. In-process determinism: a steps-budget threaded run through the
//!    default `InProcTransport` replays bitwise (the refactor did not
//!    perturb the channel protocol).
//! 2. Library-level TCP: `serve` + `join_remote` across real sockets in
//!    one process — the dense run's final parameters match the in-process
//!    threaded run bit for bit, and the byte counters differ exactly by
//!    the documented frame overhead (DESIGN.md §2.6).
//! 3. True multi-process: `hybrid-sgd serve` and `hybrid-sgd join` child
//!    processes on a loopback port, compared bitwise against an
//!    in-process `hybrid-sgd train` via their `--metrics-out` JSON, plus
//!    a two-worker `--compress topk:0.01` run over real sockets.

mod common;

use common::{fixture, inputs_for};
use hybrid_sgd::coordinator::{
    join_remote, serve, train, DelayModel, Policy, TrainConfig, WireFormat,
};
use hybrid_sgd::transport::frame::FRAME_OVERHEAD;
use hybrid_sgd::transport::msg::{
    GRAD_DENSE_HEADER_BYTES, SUBMIT_HEADER_BYTES,
};
use hybrid_sgd::transport::NetOptions;
use std::io::{BufRead, BufReader, Read};
use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// Per-(submission, shard) overhead of the dense TCP path over the
/// in-process payload accounting: frame header + CRC + submit header +
/// dense payload header.
const DENSE_SUBMIT_OVERHEAD: u64 =
    (FRAME_OVERHEAD + SUBMIT_HEADER_BYTES + GRAD_DENSE_HEADER_BYTES) as u64;

fn steps_cfg(workers: usize, shards: usize, steps: u64) -> TrainConfig {
    let mut tc = TrainConfig::quick(Policy::Async, workers, 30.0);
    tc.delay = DelayModel::none();
    tc.lr = 0.05;
    tc.shards = shards;
    tc.steps = Some(steps);
    tc.seed = 5;
    tc
}

fn quick_net() -> NetOptions {
    NetOptions {
        hb_interval: Duration::from_millis(100),
        hb_timeout: Duration::from_secs(3),
        connect_timeout: Duration::from_secs(5),
        reconnect_attempts: 2,
        ..NetOptions::default()
    }
}

fn bits(params: &[f32]) -> Vec<u32> {
    params.iter().map(|p| p.to_bits()).collect()
}

#[test]
fn inproc_steps_budget_replays_bitwise() {
    // One worker + a step budget serializes the whole pipeline: the run is
    // a pure function of the seed, so the threaded stack over the default
    // InProcTransport must replay bit for bit — the golden trace the TCP
    // comparison below builds on.
    let fx = fixture(31);
    let inputs = inputs_for(&fx, 1);
    let tc = steps_cfg(1, 2, 20);
    let a = train(&tc, &inputs).expect("run a");
    let b = train(&tc, &inputs).expect("run b");
    assert_eq!(a.gradients_total, 20);
    assert_eq!(a.gradients_total, b.gradients_total);
    assert_eq!(a.updates_total, b.updates_total);
    assert!(!a.final_params.is_empty());
    assert_eq!(bits(&a.final_params), bits(&b.final_params));
    // steps mode ends well before the 30 s hard deadline
    assert!(a.wall_time < 15.0, "took {}s", a.wall_time);
}

#[test]
fn tcp_dense_matches_inproc_bitwise_with_frame_overhead() {
    let fx = fixture(32);
    let inputs = inputs_for(&fx, 1);
    for shards in [1usize, 2] {
        let tc = steps_cfg(1, shards, 25);
        let m_inproc = train(&tc, &inputs).expect("inproc run");
        assert_eq!(m_inproc.gradients_total, 25);

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = format!("{}", listener.local_addr().unwrap());
        let net = quick_net();
        let m_tcp = std::thread::scope(|s| {
            let tc_ref = &tc;
            let inputs_ref = &inputs;
            let net_ref = &net;
            let server = s.spawn(move || serve(tc_ref, inputs_ref, listener, net_ref));
            let report = join_remote(
                &addr,
                &net,
                WireFormat::Dense,
                DelayModel::none(),
                tc.seed,
                Duration::ZERO,
                Some(25),
                Duration::from_secs(30),
                std::sync::Arc::clone(&inputs.worker_engine),
                std::sync::Arc::clone(&inputs.batch_source),
                Some(1),
                None,
            )
            .expect("join_remote");
            assert_eq!(report.grads_sent, 25);
            server.join().expect("server thread").expect("serve run")
        });

        // The learning outcome is identical, bit for bit.
        assert_eq!(m_tcp.gradients_total, m_inproc.gradients_total, "S={shards}");
        assert_eq!(m_tcp.updates_total, m_inproc.updates_total, "S={shards}");
        assert_eq!(
            bits(&m_tcp.final_params),
            bits(&m_inproc.final_params),
            "S={shards}: TCP parameters diverged from the in-process run"
        );
        // Byte counters differ only by the documented frame overhead:
        // per submission, each of the S shard frames adds the fixed
        // header+CRC bytes on top of its payload slice.
        let expected_overhead = m_inproc.gradients_total * shards as u64 * DENSE_SUBMIT_OVERHEAD;
        assert_eq!(
            m_tcp.bytes_received,
            m_inproc.bytes_received + expected_overhead,
            "S={shards}: frame-granularity accounting off"
        );
        assert_eq!(m_tcp.bytes_sent, m_tcp.bytes_received);
        assert_eq!(m_tcp.bytes_dense_equiv, m_inproc.bytes_dense_equiv);
    }
}

#[test]
fn tcp_delta_snapshots_match_full_snapshots_bitwise() {
    // Acceptance for the big-model refresh path: with `--param-dtype f32`,
    // serving every snapshot response as chunked SnapshotDelta frames
    // (snap_full_max = 0) must leave the learning outcome bitwise-identical
    // to the legacy full-SnapshotSlice protocol — the delta path is a wire
    // optimization, never a numeric one.
    let fx = fixture(35);
    let inputs = inputs_for(&fx, 1);
    for shards in [1usize, 2] {
        let tc = steps_cfg(1, shards, 25);
        let mut finals: Vec<Vec<u32>> = Vec::new();
        let mut refresh_bytes: Vec<u64> = Vec::new();
        for snap_full_max in [usize::MAX, 0] {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = format!("{}", listener.local_addr().unwrap());
            let net = NetOptions {
                snap_full_max,
                ..quick_net()
            };
            let m = std::thread::scope(|s| {
                let tc_ref = &tc;
                let inputs_ref = &inputs;
                let net_ref = &net;
                let server = s.spawn(move || serve(tc_ref, inputs_ref, listener, net_ref));
                let report = join_remote(
                    &addr,
                    &net,
                    WireFormat::Dense,
                    DelayModel::none(),
                    tc.seed,
                    Duration::ZERO,
                    Some(25),
                    Duration::from_secs(30),
                    std::sync::Arc::clone(&inputs.worker_engine),
                    std::sync::Arc::clone(&inputs.batch_source),
                    Some(1),
                    None,
                )
                .expect("join_remote");
                assert_eq!(report.grads_sent, 25);
                refresh_bytes.push(report.refresh_bytes);
                server.join().expect("server thread").expect("serve run")
            });
            finals.push(bits(&m.final_params));
        }
        assert_eq!(
            finals[0], finals[1],
            "S={shards}: delta-snapshot run diverged from the full-snapshot run"
        );
        // Both protocols measured their pull volume over the wire.
        assert!(refresh_bytes.iter().all(|&b| b > 0), "S={shards}");
    }
}

#[test]
fn tcp_topk_two_workers_train_over_localhost() {
    let fx = fixture(33);
    let inputs = inputs_for(&fx, 2);
    let mut tc = steps_cfg(2, 2, 15);
    tc.policy = Policy::Async;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = format!("{}", listener.local_addr().unwrap());
    let net = quick_net();
    let wire = WireFormat::parse("topk:0.01").unwrap();
    let m = std::thread::scope(|s| {
        let tc_ref = &tc;
        let inputs_ref = &inputs;
        let net_ref = &net;
        let server = s.spawn(move || serve(tc_ref, inputs_ref, listener, net_ref));
        let mut joins = Vec::new();
        for _ in 0..2 {
            let addr = addr.clone();
            let net = net.clone();
            let wire = wire.clone();
            let engine = std::sync::Arc::clone(&inputs.worker_engine);
            let source = std::sync::Arc::clone(&inputs.batch_source);
            joins.push(s.spawn(move || {
                join_remote(
                    &addr,
                    &net,
                    wire,
                    DelayModel::none(),
                    5,
                    Duration::ZERO,
                    Some(15),
                    Duration::from_secs(30),
                    engine,
                    source,
                    Some(2),
                    None,
                )
            }));
        }
        for j in joins {
            let report = j.join().expect("join thread").expect("join_remote");
            assert_eq!(report.grads_sent, 15);
            assert!(report.bytes_sent > 0);
        }
        server.join().expect("server thread").expect("serve run")
    });
    // Both workers' budgets arrived and were applied.
    assert_eq!(m.gradients_total, 30);
    assert!(m.updates_total > 0);
    assert!(m.final_params.iter().all(|p| p.is_finite()));
    // topk:0.01 over TCP still crushes the byte volume (1% density plus
    // fixed frame headers ≪ dense f32).
    assert!(m.bytes_sent > 0);
    assert!(
        m.wire_compression() > 5.0,
        "compression only {:.1}x",
        m.wire_compression()
    );
}

/// Elastic membership over TCP (ISSUE 5 acceptance): a full-sync run
/// survives a permanent worker departure. Worker A spends a 5-step budget
/// and leaves; with static membership the sync barrier would starve B
/// forever — under `--elastic` A's clean `Leave` renormalizes the barrier
/// to the lone survivor, which completes its full 30-step budget solo.
#[test]
fn tcp_elastic_sync_survives_early_worker_departure() {
    let fx = fixture(34);
    let inputs = inputs_for(&fx, 2);
    let mut tc = steps_cfg(2, 1, 30);
    tc.policy = Policy::Sync;
    tc.elastic = true;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = format!("{}", listener.local_addr().unwrap());
    let net = quick_net();
    let m = std::thread::scope(|s| {
        let tc_ref = &tc;
        let inputs_ref = &inputs;
        let net_ref = &net;
        let server = s.spawn(move || serve(tc_ref, inputs_ref, listener, net_ref));
        let mut joins = Vec::new();
        for steps in [5u64, 30] {
            let addr = addr.clone();
            let net = net.clone();
            let engine = std::sync::Arc::clone(&inputs.worker_engine);
            let source = std::sync::Arc::clone(&inputs.batch_source);
            let handle = s.spawn(move || {
                join_remote(
                    &addr,
                    &net,
                    WireFormat::Dense,
                    DelayModel::none(),
                    5,
                    Duration::ZERO,
                    Some(steps),
                    Duration::from_secs(60),
                    engine,
                    source,
                    Some(2),
                    None,
                )
            });
            joins.push((steps, handle));
        }
        for (steps, j) in joins {
            let report = j.join().expect("join thread").expect("join_remote");
            assert_eq!(report.grads_sent, steps, "worker must spend its full budget");
        }
        server.join().expect("server thread").expect("serve run")
    });
    // 5 joint submissions from A + 30 from B all arrived and were applied:
    // 5 barrier flushes of 2, then 25 solo flushes of 1 after the barrier
    // renormalized to the survivor.
    assert_eq!(m.gradients_total, 35);
    assert_eq!(m.updates_total, 30);
    assert_eq!(m.flushes, 30);
    // Membership telemetry: A's clean budget-spent leave, then B's.
    assert_eq!(m.membership_epochs, 2);
    assert_eq!(*m.membership.v.last().unwrap(), 0.0);
    assert!(m.final_params.iter().all(|p| p.is_finite()));
}

// ---------------------------------------------------------------------------
// true multi-process runs via the hybrid-sgd binary
// ---------------------------------------------------------------------------

struct ChildGuard(Child, &'static str);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
    }
}

/// Wait for a child with a hard deadline; returns (exit ok, stdout+stderr).
fn wait_with_deadline(mut child: ChildGuard, deadline: Duration) -> (bool, String) {
    let start = Instant::now();
    loop {
        match child.0.try_wait().expect("try_wait") {
            Some(status) => {
                let mut out = String::new();
                if let Some(mut o) = child.0.stdout.take() {
                    let _ = o.read_to_string(&mut out);
                }
                if let Some(mut e) = child.0.stderr.take() {
                    let _ = e.read_to_string(&mut out);
                }
                return (status.success(), out);
            }
            None => {
                if start.elapsed() > deadline {
                    let _ = child.0.kill();
                    panic!("{} did not exit within {deadline:?}", child.1);
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_hybrid-sgd"))
}

/// Shared workload flags: every process must describe the same run.
fn common_flags(cmd: &mut Command, workers: usize, steps: u64) {
    cmd.args([
        "--quick",
        "--engine",
        "native",
        "--dataset",
        "random",
        "--policy",
        "async",
        "--workers",
        &workers.to_string(),
        "--steps",
        &steps.to_string(),
        "--seed",
        "7",
        "--delay-std",
        "0",
        "--compute-ms",
        "0",
        "--secs",
        "30",
    ]);
}

fn read_params_bits(path: &std::path::Path) -> (Vec<u32>, f64, f64) {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let json = hybrid_sgd::util::json::parse(&text).expect("metrics JSON parses");
    let params: Vec<u32> = json
        .get("final_params")
        .expect("final_params present")
        .as_arr()
        .expect("final_params is an array")
        .iter()
        .map(|v| (v.as_f64().expect("param is a number") as f32).to_bits())
        .collect();
    let grads = json.f64_field("gradients_total").expect("gradients_total");
    let bytes_received = json.f64_field("bytes_received").expect("bytes_received");
    (params, grads, bytes_received)
}

/// Spawn `serve`, parse the bound address from its stdout, hand back the
/// child (stdout is drained by the returned reader thread).
fn spawn_serve(
    workers: usize,
    steps: u64,
    metrics_out: &std::path::Path,
) -> (ChildGuard, String, std::thread::JoinHandle<String>) {
    let mut cmd = bin();
    cmd.arg("serve").args(["--listen", "127.0.0.1:0"]);
    common_flags(&mut cmd, workers, steps);
    cmd.args(["--metrics-out", metrics_out.to_str().unwrap()]);
    cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
    let mut child = cmd.spawn().expect("spawn serve");
    let stdout = child.stdout.take().expect("serve stdout");
    let mut reader = BufReader::new(stdout);
    let mut addr = None;
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut line = String::new();
    while addr.is_none() {
        assert!(Instant::now() < deadline, "serve never reported its address");
        line.clear();
        let n = reader.read_line(&mut line).expect("read serve stdout");
        assert!(n > 0, "serve exited before reporting its address");
        // "listening       : 127.0.0.1:PORT"
        if let Some(rest) = line.strip_prefix("listening") {
            let a = rest.trim_start_matches(|c| c == ' ' || c == ':').trim();
            addr = Some(a.to_string());
        }
    }
    // Drain the rest of stdout in the background so the child never blocks
    // on a full pipe.
    let drain = std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = reader.read_to_string(&mut rest);
        rest
    });
    (ChildGuard(child, "serve"), addr.unwrap(), drain)
}

#[test]
fn multiprocess_dense_tcp_matches_inproc_train_bitwise() {
    let dir = std::env::temp_dir().join(format!(
        "hybrid-sgd-transport-{}-{}",
        std::process::id(),
        line!()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let inproc_json = dir.join("inproc.json");
    let tcp_json = dir.join("tcp.json");

    // 1. The in-process reference run (`hybrid-sgd train`).
    let mut cmd = bin();
    cmd.arg("train");
    common_flags(&mut cmd, 1, 40);
    cmd.args(["--metrics-out", inproc_json.to_str().unwrap()]);
    cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
    let (ok, out) = wait_with_deadline(
        ChildGuard(cmd.spawn().expect("spawn train"), "train"),
        Duration::from_secs(60),
    );
    assert!(ok, "train failed:\n{out}");

    // 2. The same run split across processes: serve + one join.
    let (server, addr, drain) = spawn_serve(1, 40, &tcp_json);
    let mut cmd = bin();
    cmd.arg("join").args(["--connect", &addr]);
    common_flags(&mut cmd, 1, 40);
    cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
    let (ok, out) = wait_with_deadline(
        ChildGuard(cmd.spawn().expect("spawn join"), "join"),
        Duration::from_secs(60),
    );
    assert!(ok, "join failed:\n{out}");
    let (ok, out) = wait_with_deadline(server, Duration::from_secs(60));
    assert!(ok, "serve failed:\n{out}");
    let _ = drain.join();

    // 3. Bitwise parameter equality; byte counters differ exactly by the
    //    frame overhead of 40 dense submissions × 1 shard.
    let (p_in, g_in, b_in) = read_params_bits(&inproc_json);
    let (p_tcp, g_tcp, b_tcp) = read_params_bits(&tcp_json);
    assert_eq!(g_in, 40.0);
    assert_eq!(g_tcp, 40.0);
    assert!(!p_in.is_empty());
    assert_eq!(
        p_in, p_tcp,
        "multi-process dense run diverged from the in-process one"
    );
    assert_eq!(b_tcp as u64, b_in as u64 + 40 * DENSE_SUBMIT_OVERHEAD);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ISSUE-5 chaos scenario, end to end across real processes: serve
/// `--elastic` with 3 worker slots, three `join` processes, SIGKILL one
/// mid-run, start a replacement that takes the freed slot — the run
/// completes every surviving worker's step budget, and the membership
/// epoch count matches the same churn replayed on the virtual-time
/// simulator (kill ≙ `leave`, replacement ≙ `join:+1`, plus one clean
/// budget-spent departure per finishing worker).
#[test]
fn multiprocess_elastic_chaos_kill_and_replace_matches_sim_epochs() {
    use hybrid_sgd::coordinator::sim::{simulate, Scenario};

    let dir = std::env::temp_dir().join(format!(
        "hybrid-sgd-transport-{}-{}",
        std::process::id(),
        line!()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let tcp_json = dir.join("chaos.json");

    let chaos_flags = |cmd: &mut Command| {
        cmd.args([
            "--quick",
            "--engine",
            "native",
            "--dataset",
            "random",
            "--policy",
            "hybrid:step:20",
            "--workers",
            "3",
            "--steps",
            "80",
            "--seed",
            "7",
            "--delay-std",
            "0",
            "--compute-ms",
            "10",
            "--secs",
            "45",
        ]);
    };

    // serve --elastic
    let (server, addr, drain) = {
        let mut cmd = bin();
        cmd.arg("serve").args(["--listen", "127.0.0.1:0", "--elastic"]);
        chaos_flags(&mut cmd);
        cmd.args(["--metrics-out", tcp_json.to_str().unwrap()]);
        cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
        let mut child = cmd.spawn().expect("spawn serve");
        let stdout = child.stdout.take().expect("serve stdout");
        let mut reader = BufReader::new(stdout);
        let mut addr = None;
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut line = String::new();
        while addr.is_none() {
            assert!(Instant::now() < deadline, "serve never reported its address");
            line.clear();
            let n = reader.read_line(&mut line).expect("read serve stdout");
            assert!(n > 0, "serve exited before reporting its address");
            if let Some(rest) = line.strip_prefix("listening") {
                addr = Some(rest.trim_start_matches(|c| c == ' ' || c == ':').trim().to_string());
            }
        }
        let drain = std::thread::spawn(move || {
            let mut rest = String::new();
            let _ = reader.read_to_string(&mut rest);
            rest
        });
        (ChildGuard(child, "serve"), addr.unwrap(), drain)
    };

    // The victim: spawn first and wait on its stderr for the attach log
    // line, so the SIGKILL provably lands on a *member* of the run.
    let mut victim = {
        let mut cmd = bin();
        cmd.arg("join").args(["--connect", &addr]);
        chaos_flags(&mut cmd);
        cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
        ChildGuard(cmd.spawn().expect("spawn victim join"), "victim join")
    };
    let victim_stderr = victim.0.stderr.take().expect("victim stderr");
    let mut err_reader = BufReader::new(victim_stderr);
    {
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut line = String::new();
        loop {
            assert!(Instant::now() < deadline, "victim never attached");
            line.clear();
            let n = err_reader.read_line(&mut line).expect("read victim stderr");
            assert!(n > 0, "victim exited before attaching");
            if line.contains("joined") && line.contains("as worker") {
                break;
            }
        }
    }
    let err_drain = std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = err_reader.read_to_string(&mut rest);
    });

    // Two survivors.
    let mut survivors = Vec::new();
    for _ in 0..2 {
        let mut cmd = bin();
        cmd.arg("join").args(["--connect", &addr]);
        chaos_flags(&mut cmd);
        cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
        survivors.push(ChildGuard(cmd.spawn().expect("spawn join"), "join"));
    }

    // Let the cluster train a little, then SIGKILL the victim mid-run (80
    // steps at a 10 ms floor run ≥ 800 ms, so 300 ms is mid-budget).
    std::thread::sleep(Duration::from_millis(300));
    victim.0.kill().expect("kill victim");
    let _ = victim.0.wait(); // reap the killed process
    let _ = err_drain.join();
    // Give the server a beat to reap the dead connection (it reads the
    // killed socket's FIN within one poll), then start the replacement,
    // which must be admitted into the freed slot.
    std::thread::sleep(Duration::from_millis(200));
    let replacement = {
        let mut cmd = bin();
        cmd.arg("join").args(["--connect", &addr]);
        chaos_flags(&mut cmd);
        cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
        ChildGuard(cmd.spawn().expect("spawn replacement join"), "replacement join")
    };

    for j in survivors {
        let (ok, out) = wait_with_deadline(j, Duration::from_secs(60));
        assert!(ok, "surviving join failed:\n{out}");
    }
    let (ok, out) = wait_with_deadline(replacement, Duration::from_secs(60));
    assert!(ok, "replacement join failed:\n{out}");
    let (ok, out) = wait_with_deadline(server, Duration::from_secs(60));
    assert!(ok, "serve failed:\n{out}");
    let _ = drain.join();
    drop(victim); // already killed and reaped; the guard's kill is a no-op

    let text = std::fs::read_to_string(&tcp_json).expect("metrics artifact written");
    let json = hybrid_sgd::util::json::parse(&text).expect("metrics JSON parses");
    // The two survivors and the replacement completed their full budgets;
    // the victim contributed whatever it managed before the kill.
    let grads = json.f64_field("gradients_total").unwrap();
    assert!(grads >= 240.0, "step budgets not reached: {grads} gradients");
    assert!(json.f64_field("updates_total").unwrap() > 0.0);
    let tcp_epochs = json.f64_field("membership_epochs").unwrap() as u64;

    // Replay the same churn on the simulator: one mid-run departure, one
    // joiner, and a clean budget-spent departure for each of the three
    // finishing workers — the membership-epoch count must agree.
    let fx = fixture(35);
    let inputs = inputs_for(&fx, 3);
    let scn = Scenario::parse(
        "workers=3 policy=hybrid:step:20 secs=45 steps=80 grad-ms=10 elastic=on \
         faults=leave:1@0.5,join:+1@0.6",
    )
    .unwrap();
    let sim = simulate(&scn, &inputs).unwrap();
    assert_eq!(
        sim.membership_epochs, 5,
        "sim churn: kill-leave + replacement-join + 3 budget departures"
    );
    assert_eq!(
        tcp_epochs, sim.membership_epochs,
        "TCP and simulator disagree on membership epochs"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn multiprocess_topk_smoke_two_workers() {
    let dir = std::env::temp_dir().join(format!(
        "hybrid-sgd-transport-{}-{}",
        std::process::id(),
        line!()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let tcp_json = dir.join("metrics.json");
    let (server, addr, drain) = spawn_serve(2, 25, &tcp_json);
    let mut joins = Vec::new();
    for _ in 0..2 {
        let mut cmd = bin();
        cmd.arg("join")
            .args(["--connect", &addr, "--compress", "topk:0.01"]);
        common_flags(&mut cmd, 2, 25);
        cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
        joins.push(ChildGuard(cmd.spawn().expect("spawn join"), "join"));
    }
    for j in joins {
        let (ok, out) = wait_with_deadline(j, Duration::from_secs(60));
        assert!(ok, "join failed:\n{out}");
    }
    let (ok, out) = wait_with_deadline(server, Duration::from_secs(60));
    assert!(ok, "serve failed:\n{out}");
    let _ = drain.join();
    let text = std::fs::read_to_string(&tcp_json).expect("metrics artifact written");
    let json = hybrid_sgd::util::json::parse(&text).expect("metrics JSON parses");
    // both workers reached the step budget: 2 × 25 submissions arrived
    assert_eq!(json.f64_field("gradients_total").unwrap(), 50.0);
    assert!(json.f64_field("updates_total").unwrap() > 0.0);
    // compressed TCP run actually compresses
    assert!(json.f64_field("wire_compression").unwrap() > 5.0);
    let _ = std::fs::remove_dir_all(&dir);
}
