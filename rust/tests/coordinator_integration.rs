//! Threaded coordinator integration over the native engine: full PS +
//! workers + evaluator runs exercising every policy, delay injection,
//! shutdown paths and failure injection. No artifacts required.

use hybrid_sgd::coordinator::worker::BatchSource;
use hybrid_sgd::coordinator::{
    train, DelayModel, EvalSet, Policy, RunInputs, RunMetrics, Schedule, TrainConfig,
};
use hybrid_sgd::data::{random_cluster, Batcher, Dataset};
use hybrid_sgd::engine::{factory, GradEngine};
use hybrid_sgd::native::MlpEngine;
use hybrid_sgd::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Duration;

const DIMS: [usize; 3] = [20, 32, 10];

struct Fixture {
    train_set: Arc<Dataset>,
    test: EvalSet,
    probe: EvalSet,
    init: Vec<f32>,
}

fn fixture(seed: u64) -> Fixture {
    let mut rng = Pcg64::seeded(seed);
    let spec = random_cluster::ClusterSpec {
        n_samples: 1000,
        ..Default::default()
    };
    let full = random_cluster::generate(&spec, &mut rng);
    let (train_set, test_set) = full.split(0.8, &mut rng);
    let test = EvalSet::from_dataset(&test_set, 200, &mut rng);
    let probe = EvalSet::from_dataset(&train_set, 200, &mut rng);
    let init = MlpEngine::init_params(&DIMS, &mut rng);
    Fixture {
        train_set: Arc::new(train_set),
        test,
        probe,
        init,
    }
}

fn run(fx: &Fixture, policy: Policy, workers: usize, secs: f64, delay: DelayModel) -> RunMetrics {
    run_sharded(fx, policy, workers, secs, delay, 1)
}

fn run_sharded(
    fx: &Fixture,
    policy: Policy,
    workers: usize,
    secs: f64,
    delay: DelayModel,
    shards: usize,
) -> RunMetrics {
    hybrid_sgd::util::logging::set_level(hybrid_sgd::util::logging::Level::Off);
    let batch = 16;
    let dims: Vec<usize> = DIMS.to_vec();
    let dims2 = dims.clone();
    let shards = fx.train_set.shard_indices(workers);
    let train_arc = Arc::clone(&fx.train_set);
    let inputs = RunInputs {
        worker_engine: factory(move || {
            Ok(Box::new(MlpEngine::new(dims.clone(), batch)) as Box<dyn GradEngine>)
        }),
        eval_engine: factory(move || {
            Ok(Box::new(MlpEngine::new(dims2.clone(), 50)) as Box<dyn GradEngine>)
        }),
        batch_source: Arc::new(move |id| {
            Box::new(Batcher::new(
                Arc::clone(&train_arc),
                shards[id].clone(),
                batch,
                Pcg64::new(11, id as u64),
            )) as Box<dyn BatchSource>
        }),
        init_params: &fx.init,
        test: &fx.test,
        train_probe: &fx.probe,
    };
    let cfg = TrainConfig {
        policy,
        workers,
        lr: 0.05,
        duration: Duration::from_secs_f64(secs),
        delay,
        seed: 5,
        eval_interval: Duration::from_millis(200),
        k_max: None,
        compute_floor: Duration::ZERO,
        shards,
    };
    train(&cfg, &inputs).expect("train failed")
}

#[test]
fn all_policies_complete_and_learn() {
    let fx = fixture(1);
    for policy in [
        Policy::Async,
        Policy::Sync,
        Policy::Hybrid {
            schedule: Schedule::Step { step: 60 },
            strict: false,
        },
        Policy::Hybrid {
            schedule: Schedule::Step { step: 60 },
            strict: true,
        },
    ] {
        let m = run(&fx, policy.clone(), 4, 1.5, DelayModel::none());
        assert!(m.gradients_total > 10, "{policy}: {} grads", m.gradients_total);
        let last = *m.test_acc.v.last().unwrap();
        assert!(last > 30.0, "{policy}: final acc {last}");
    }
}

#[test]
fn sharded_server_completes_every_policy() {
    // The tentpole invariant, end to end: the sharded parameter server with
    // S ∈ {2, 4} trains every policy through the full threaded stack.
    let fx = fixture(8);
    for shards in [2usize, 4] {
        for policy in [
            Policy::Async,
            Policy::Sync,
            Policy::Hybrid {
                schedule: Schedule::Step { step: 60 },
                strict: false,
            },
        ] {
            let m = run_sharded(&fx, policy.clone(), 3, 1.5, DelayModel::none(), shards);
            assert_eq!(m.shards, shards, "{policy}: shard count");
            assert!(
                m.gradients_total > 10,
                "{policy} S={shards}: {} grads",
                m.gradients_total
            );
            let last = *m.test_acc.v.last().unwrap();
            assert!(last > 30.0, "{policy} S={shards}: final acc {last}");
        }
    }
}

#[test]
fn delays_slow_down_but_do_not_break() {
    let fx = fixture(2);
    let fast = run(&fx, Policy::Async, 4, 1.5, DelayModel::none());
    let slow = run(
        &fx,
        Policy::Async,
        4,
        1.5,
        DelayModel {
            affected_fraction: 1.0,
            mean: 0.05,
            std: 0.05,
        },
    );
    assert!(
        slow.grads_per_sec() < fast.grads_per_sec() * 0.8,
        "delays had no effect: {} vs {}",
        slow.grads_per_sec(),
        fast.grads_per_sec()
    );
    assert!(slow.gradients_total > 5);
}

#[test]
fn delayed_half_creates_imbalance() {
    let fx = fixture(3);
    let m = run(&fx, Policy::Async, 4, 1.5, DelayModel::paper_default());
    // 2 of 4 workers are delayed: their gradient counts must lag
    assert!(
        m.worker_imbalance() > 1.5,
        "expected heterogeneity, got imbalance {}",
        m.worker_imbalance()
    );
}

#[test]
fn sync_produces_fewer_updates_than_async() {
    let fx = fixture(4);
    let a = run(&fx, Policy::Async, 4, 1.0, DelayModel::none());
    let s = run(&fx, Policy::Sync, 4, 1.0, DelayModel::none());
    assert!(s.updates_total < a.updates_total / 2);
    assert_eq!(a.updates_total, a.gradients_total);
}

#[test]
fn hybrid_k_trajectory_monotone_and_staleness_lower_than_async() {
    let fx = fixture(5);
    let h = run(
        &fx,
        Policy::Hybrid {
            schedule: Schedule::Step { step: 40 },
            strict: false,
        },
        4,
        1.5,
        DelayModel::none(),
    );
    for w in h.k_trajectory.v.windows(2) {
        assert!(w[1] >= w[0], "K not monotone");
    }
    let a = run(&fx, Policy::Async, 4, 1.5, DelayModel::none());
    assert!(
        h.mean_staleness < a.mean_staleness,
        "hybrid staleness {} !< async {}",
        h.mean_staleness,
        a.mean_staleness
    );
}

#[test]
fn engine_failure_is_survived() {
    // A worker whose engine errors exits cleanly; the rest of the run
    // completes and reports.
    struct FlakyEngine {
        calls: u32,
        inner: MlpEngine,
    }
    impl GradEngine for FlakyEngine {
        fn param_count(&self) -> usize {
            self.inner.param_count()
        }
        fn batch_size(&self) -> usize {
            self.inner.batch_size()
        }
        fn grad(
            &mut self,
            p: &[f32],
            x: &[f32],
            y: &[i32],
            g: &mut [f32],
        ) -> anyhow::Result<f32> {
            self.calls += 1;
            anyhow::ensure!(self.calls < 5, "injected failure");
            self.inner.grad(p, x, y, g)
        }
        fn eval(&mut self, p: &[f32], x: &[f32], y: &[i32]) -> anyhow::Result<(f64, usize)> {
            self.inner.eval(p, x, y)
        }
    }
    hybrid_sgd::util::logging::set_level(hybrid_sgd::util::logging::Level::Off);
    let fx = fixture(6);
    let dims: Vec<usize> = DIMS.to_vec();
    let dims2 = dims.clone();
    let shards = fx.train_set.shard_indices(3);
    let train_arc = Arc::clone(&fx.train_set);
    let inputs = RunInputs {
        worker_engine: factory(move || {
            Ok(Box::new(FlakyEngine {
                calls: 0,
                inner: MlpEngine::new(dims.clone(), 16),
            }) as Box<dyn GradEngine>)
        }),
        eval_engine: factory(move || {
            Ok(Box::new(MlpEngine::new(dims2.clone(), 50)) as Box<dyn GradEngine>)
        }),
        batch_source: Arc::new(move |id| {
            Box::new(Batcher::new(
                Arc::clone(&train_arc),
                shards[id].clone(),
                16,
                Pcg64::new(13, id as u64),
            )) as Box<dyn BatchSource>
        }),
        init_params: &fx.init,
        test: &fx.test,
        train_probe: &fx.probe,
    };
    let cfg = TrainConfig::quick(Policy::Async, 3, 0.8);
    let m = train(&cfg, &inputs).expect("run should survive worker failures");
    // each of the 3 workers produced at most 4 gradients before failing
    assert!(m.gradients_total <= 12);
}

#[test]
fn identical_seeds_reproduce_gradient_counts_in_sync() {
    // Sync is deterministic in its update *values* given the same batches;
    // wall-clock variation only changes how many rounds fit.
    let fx = fixture(7);
    let a = run(&fx, Policy::Sync, 3, 1.0, DelayModel::none());
    let b = run(&fx, Policy::Sync, 3, 1.0, DelayModel::none());
    // both runs complete with a sane flush/update structure
    assert_eq!(a.updates_total, a.flushes);
    assert_eq!(b.updates_total, b.flushes);
}
