//! Coordinator integration over the native engine.
//!
//! The policy × delay-model matrix runs on the **virtual clock**: the
//! deterministic discrete-event simulator (`coordinator::sim`) replays the
//! full PS + workers + evaluator pipeline in virtual time, so what used to
//! be multi-second wall-clock sleeps per case now completes in
//! milliseconds and reproduces bitwise. Two tests still drive the threaded
//! real-clock stack end to end; their names carry the `real_clock` prefix
//! so CI's virtual-clock matrix job can `--skip real_clock`.

mod common;

use common::{fixture, flaky_inputs, inputs_for, Fixture};
use hybrid_sgd::coordinator::sim::{simulate, FaultPlan, Scenario};
use hybrid_sgd::coordinator::{train, DelayModel, Policy, RunMetrics, Schedule, TrainConfig};
use std::time::Duration;

fn train_cfg(
    policy: Policy,
    workers: usize,
    secs: f64,
    delay: DelayModel,
    shards: usize,
) -> TrainConfig {
    TrainConfig {
        policy,
        workers,
        lr: 0.05,
        duration: Duration::from_secs_f64(secs),
        delay,
        seed: 5,
        eval_interval: Duration::from_millis(200),
        k_max: None,
        compute_floor: Duration::ZERO,
        shards,
        wire: hybrid_sgd::coordinator::WireFormat::Dense,
        steps: None,
        elastic: false,
        min_quorum: 1,
        stream: None,
        aggregate: hybrid_sgd::coordinator::AggregateMode::Mean,
        partition: hybrid_sgd::data::Partition::Iid,
        trace: None,
        param_dtype: hybrid_sgd::coordinator::ParamDtype::F32,
    }
}

/// One run on the virtual clock: `secs` *virtual* seconds at 5 ms per
/// gradient — wall time is milliseconds regardless of `secs`.
fn sim_run(
    fx: &Fixture,
    policy: Policy,
    workers: usize,
    secs: f64,
    delay: DelayModel,
    shards: usize,
) -> RunMetrics {
    let inputs = inputs_for(fx, workers);
    let scn = Scenario {
        train: train_cfg(policy, workers, secs, delay, shards),
        grad_time: Duration::from_millis(5),
        faults: FaultPlan::default(),
    };
    simulate(&scn, &inputs).expect("sim failed")
}

fn hybrid_step(step: usize) -> Policy {
    Policy::Hybrid {
        schedule: Schedule::Step { step },
        strict: false,
    }
}

#[test]
fn all_policies_complete_and_learn() {
    let fx = fixture(1);
    for policy in [
        Policy::Async,
        Policy::Sync,
        hybrid_step(60),
        Policy::Hybrid {
            schedule: Schedule::Step { step: 60 },
            strict: true,
        },
    ] {
        let m = sim_run(&fx, policy.clone(), 4, 2.0, DelayModel::none(), 1);
        assert!(m.gradients_total > 10, "{policy}: {} grads", m.gradients_total);
        let last = *m.test_acc.v.last().unwrap();
        assert!(last > 30.0, "{policy}: final acc {last}");
    }
}

#[test]
fn every_policy_by_every_delay_model_completes() {
    // The paper's §6 matrix: policy × delay model, all on the virtual
    // clock. Structural assertions only — accuracy under heavy injected
    // delay is covered by the dedicated tests below.
    let fx = fixture(9);
    let delays = [
        DelayModel::none(),
        DelayModel::paper_default(),
        DelayModel::paper_default().with_std(0.1),
    ];
    for policy in [Policy::Async, Policy::Sync, hybrid_step(40)] {
        for delay in &delays {
            let m = sim_run(&fx, policy.clone(), 4, 1.0, delay.clone(), 1);
            assert!(
                m.gradients_total > 5,
                "{policy} under {delay:?}: {} grads",
                m.gradients_total
            );
            assert!(m.updates_total > 0, "{policy} under {delay:?}: no updates");
            assert_eq!(m.shards, 1);
        }
    }
}

#[test]
fn sharded_server_completes_every_policy() {
    // In the simulator the lockstep invariant is exact: every shard sees
    // the identical arrival sequence, so per-shard update counts agree
    // exactly (the threaded stack allows in-flight skew at shutdown).
    let fx = fixture(8);
    for shards in [2usize, 4] {
        for policy in [Policy::Async, Policy::Sync, hybrid_step(60)] {
            let m = sim_run(&fx, policy.clone(), 3, 2.0, DelayModel::none(), shards);
            assert_eq!(m.shards, shards, "{policy}: shard count");
            assert_eq!(m.per_shard_updates.len(), shards);
            let (min, max) = (
                *m.per_shard_updates.iter().min().unwrap(),
                *m.per_shard_updates.iter().max().unwrap(),
            );
            assert_eq!(
                min, max,
                "{policy} S={shards}: shards diverged {:?}",
                m.per_shard_updates
            );
            assert!(m.gradients_total > 10, "{policy} S={shards}");
            let last = *m.test_acc.v.last().unwrap();
            assert!(last > 30.0, "{policy} S={shards}: final acc {last}");
        }
    }
}

#[test]
fn delays_slow_down_but_do_not_break() {
    let fx = fixture(2);
    let fast = sim_run(&fx, Policy::Async, 4, 1.5, DelayModel::none(), 1);
    let slow = sim_run(
        &fx,
        Policy::Async,
        4,
        1.5,
        DelayModel {
            affected_fraction: 1.0,
            mean: 0.05,
            std: 0.05,
        },
        1,
    );
    assert!(
        slow.grads_per_sec() < fast.grads_per_sec() * 0.8,
        "delays had no effect: {} vs {}",
        slow.grads_per_sec(),
        fast.grads_per_sec()
    );
    assert!(slow.gradients_total > 5);
}

#[test]
fn delayed_half_creates_imbalance() {
    let fx = fixture(3);
    let m = sim_run(&fx, Policy::Async, 4, 1.5, DelayModel::paper_default(), 1);
    // 2 of 4 workers are delayed: their gradient counts must lag
    assert!(
        m.worker_imbalance() > 1.5,
        "expected heterogeneity, got imbalance {}",
        m.worker_imbalance()
    );
}

#[test]
fn sync_produces_fewer_updates_than_async() {
    let fx = fixture(4);
    let a = sim_run(&fx, Policy::Async, 4, 1.0, DelayModel::none(), 1);
    let s = sim_run(&fx, Policy::Sync, 4, 1.0, DelayModel::none(), 1);
    assert!(s.updates_total < a.updates_total / 2);
    assert_eq!(a.updates_total, a.gradients_total);
}

#[test]
fn hybrid_k_trajectory_monotone_and_staleness_lower_than_async() {
    let fx = fixture(5);
    let h = sim_run(&fx, hybrid_step(40), 4, 1.5, DelayModel::none(), 1);
    for w in h.k_trajectory.v.windows(2) {
        assert!(w[1] >= w[0], "K not monotone");
    }
    let a = sim_run(&fx, Policy::Async, 4, 1.5, DelayModel::none(), 1);
    assert!(
        h.mean_staleness < a.mean_staleness,
        "hybrid staleness {} !< async {}",
        h.mean_staleness,
        a.mean_staleness
    );
}

#[test]
fn virtual_runs_are_bitwise_reproducible() {
    // The determinism contract, on the full workload: identical seed +
    // scenario ⇒ identical RunMetrics, including under injected delays.
    let fx = fixture(6);
    let a = sim_run(&fx, hybrid_step(50), 4, 1.5, DelayModel::paper_default(), 2);
    let b = sim_run(&fx, hybrid_step(50), 4, 1.5, DelayModel::paper_default(), 2);
    assert_eq!(a, b);
}

#[test]
fn real_clock_smoke_full_stack() {
    // The one wall-clock test: the threaded PS + workers + evaluator still
    // runs end to end on the real clock.
    let fx = fixture(1);
    let inputs = inputs_for(&fx, 3);
    let cfg = train_cfg(Policy::Async, 3, 0.8, DelayModel::none(), 2);
    let m = train(&cfg, &inputs).expect("train failed");
    assert!(m.gradients_total > 5, "{} grads", m.gradients_total);
    assert_eq!(m.shards, 2);
    assert!(!m.test_acc.is_empty());
}

#[test]
fn real_clock_engine_failure_is_survived() {
    // A worker whose engine errors exits cleanly; the rest of the run
    // completes and reports (threaded path).
    let fx = fixture(6);
    let inputs = flaky_inputs(&fx, 3);
    let cfg = TrainConfig::quick(Policy::Async, 3, 0.8);
    let m = train(&cfg, &inputs).expect("run should survive worker failures");
    // each of the 3 workers produced at most 4 gradients before failing
    assert!(m.gradients_total <= 12);
}

#[test]
fn engine_failure_crashes_sim_worker_cleanly() {
    // The simulator's analogue of the threaded engine-failure test: a
    // worker whose engine errors is marked crashed; the run completes.
    let fx = fixture(7);
    let inputs = flaky_inputs(&fx, 3);
    let scn = Scenario {
        train: train_cfg(Policy::Async, 3, 2.0, DelayModel::none(), 1),
        grad_time: Duration::from_millis(5),
        faults: FaultPlan::default(),
    };
    let m = simulate(&scn, &inputs).expect("sim should survive worker failures");
    assert!(m.gradients_total <= 12, "{} grads", m.gradients_total);
    assert!(m.gradients_total > 0);
}
