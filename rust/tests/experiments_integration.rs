//! Experiment-pipeline integration: quick-scale table/figure regeneration
//! through the native engine (no artifacts needed), exercising the exact
//! code path `hybrid-sgd table N` / `figure N` runs.

use hybrid_sgd::experiments::config::{DatasetKind, EngineKind, ExpConfig};
use hybrid_sgd::experiments::figures::{comparison_csv, figure_from_table};
use hybrid_sgd::experiments::runner::{run_comparison_algos, Algo};
use hybrid_sgd::experiments::tables::Table;

fn quick_native() -> ExpConfig {
    hybrid_sgd::util::logging::set_level(hybrid_sgd::util::logging::Level::Off);
    let mut c = ExpConfig::default_for(DatasetKind::Random).quick();
    c.engine = EngineKind::Native;
    c.secs = 1.0;
    c.workers = 3;
    c.train_n = 600;
    c.test_n = 200;
    c.grid_points = 5;
    c.compute_ms = 0.0;
    c
}

#[test]
fn comparison_to_csv_roundtrip() {
    let cfg = quick_native();
    let cmp = run_comparison_algos(&cfg, &[Algo::Hybrid, Algo::Async]).unwrap();
    let csv = comparison_csv(&cmp);
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), cfg.grid_points + 1);
    assert!(lines[0].starts_with("t,hybrid_acc"));
    // every data row parses as floats
    for row in &lines[1..] {
        for cell in row.split(',') {
            cell.parse::<f64>().unwrap();
        }
    }
}

#[test]
fn diff_row_shape_and_figure() {
    let cfg = quick_native();
    let mut measured = Vec::new();
    let mut labels = Vec::new();
    for batch in [8usize, 32] {
        let mut c = cfg.clone();
        c.batch = batch;
        let cmp = run_comparison_algos(&c, &[Algo::Hybrid, Algo::Async]).unwrap();
        measured.push(cmp.diff_vs(Algo::Async).unwrap());
        labels.push(batch.to_string());
    }
    let table = Table {
        id: 3,
        title: "quick batch sweep".into(),
        col_labels: labels,
        measured,
        paper: vec![],
        comparisons: vec![],
    };
    let md = table.to_markdown();
    assert!(md.contains("Table 3"));
    assert!(md.contains("Test Accuracy"));
    let fig = figure_from_table(8, "batch size", &table);
    assert!(fig.chart.contains("Figure 8"));
    assert_eq!(fig.csv.len(), 1);
    assert!(fig.csv[0].1.lines().count() >= 3);
}

#[test]
fn paper_scale_flag_changes_config_only() {
    let base = ExpConfig::default_for(DatasetKind::Random);
    let paper = base.clone().paper_scale();
    assert_eq!(paper.workers, 25);
    assert!(paper.secs > base.secs);
    // schedule scale adapts with secs (longer run → larger effective step)
    let s_base = format!("{}", base.schedule());
    let s_paper = format!("{}", paper.schedule());
    assert_ne!(s_base, s_paper);
}

#[test]
fn identical_init_across_algorithms() {
    // The runner must hand every algorithm the same initial parameters per
    // round: first evaluation samples (t=0) must coincide.
    let cfg = quick_native();
    let cmp = run_comparison_algos(&cfg, &[Algo::Hybrid, Algo::Async, Algo::Sync]).unwrap();
    let accs: Vec<f64> = cmp.averaged.iter().map(|(_, a)| a.test_acc[0]).collect();
    for w in accs.windows(2) {
        assert!(
            (w[0] - w[1]).abs() < 1e-9,
            "t=0 accuracy differs across algorithms: {accs:?}"
        );
    }
}
