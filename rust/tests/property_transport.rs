//! Property tests over the transport frame + message codec (mini-proptest
//! harness; see `util::proptest` — the offline image has no proptest or
//! fuzzing crates).
//!
//! The contracts under test (ISSUE 4 satellite):
//! - arbitrary-bytes fuzz never panics, and every failed decode is a
//!   *typed* error — not a hang, not a silently wrong payload;
//! - truncation at every byte offset is rejected;
//! - single-bit corruption anywhere in a frame is caught (CRC32 or a
//!   structural check);
//! - encode→decode roundtrips bitwise for every `ShardGrad` variant across
//!   the wire formats and shard counts S ∈ {1, 2, 4}.

use hybrid_sgd::coordinator::compress::{GradEncoder, WireFormat};
use hybrid_sgd::coordinator::ShardLayout;
use hybrid_sgd::prop_assert;
use hybrid_sgd::transport::frame::{
    decode_frame, encode_frame_into, FrameError, FrameReader, FRAME_OVERHEAD,
};
use hybrid_sgd::transport::msg::{encode_submit_into, Msg, WireError};
use hybrid_sgd::util::proptest::{check, Gen};

fn random_bytes(g: &mut Gen, len: usize) -> Vec<u8> {
    (0..len).map(|_| g.rng.below(256) as u8).collect()
}

/// Arbitrary bytes through the frame decoder: never a panic, never a
/// false positive (the probability of random bytes carrying a valid magic,
/// version, bounded length *and* matching CRC is ~2⁻⁶⁴; with the seeded
/// generator this is deterministic, so a flake cannot occur).
#[test]
fn prop_frame_decoder_survives_arbitrary_bytes() {
    check("frame-fuzz", 300, |g| {
        let len = g.usize_in(0, 2048);
        let mut buf = random_bytes(g, len);
        match decode_frame(&buf) {
            Err(
                FrameError::Truncated { .. }
                | FrameError::BadMagic { .. }
                | FrameError::Version { .. }
                | FrameError::TooLarge { .. }
                | FrameError::Corrupt { .. },
            ) => {}
            Ok(_) => return Err("random bytes decoded as a valid frame".into()),
        }
        // The streaming reader survives the same garbage (poisoning
        // itself rather than looping or panicking).
        let mut r = FrameReader::new();
        r.feed(&buf);
        let mut payload = Vec::new();
        for _ in 0..4 {
            match r.next_frame(&mut payload) {
                Ok(true) => return Err("garbage produced a frame".into()),
                Ok(false) => break,
                Err(_) => {} // typed, sticky
            }
        }
        // ...and arbitrary bytes through the message decoder never panic.
        buf.truncate(g.usize_in(0, len));
        match Msg::decode(&buf) {
            Err(
                WireError::Truncated { .. }
                | WireError::UnknownMsg(_)
                | WireError::UnknownPayload(_)
                | WireError::Invalid(_),
            ) => {}
            // A random first byte can hit a valid tag with trivially
            // consistent contents (e.g. Shutdown = one byte): fine, the
            // decode is still well-typed.
            Ok(_) => {}
        }
        Ok(())
    });
}

/// A valid frame truncated at *every* byte offset yields `Truncated` with
/// an honest `need > have`; never a payload.
#[test]
fn prop_truncation_rejected_at_every_offset() {
    check("frame-truncation", 60, |g| {
        let payload = random_bytes(g, g.usize_in(0, 256));
        let mut wire = Vec::new();
        encode_frame_into(&payload, &mut wire);
        for cut in 0..wire.len() {
            match decode_frame(&wire[..cut]) {
                Err(FrameError::Truncated { need, have }) => {
                    prop_assert!(have == cut, "have={have} at cut={cut}");
                    prop_assert!(need > cut, "need={need} not past cut={cut}");
                }
                other => {
                    return Err(format!("cut={cut}: expected Truncated, got {other:?}"))
                }
            }
        }
        let (decoded, consumed) = decode_frame(&wire).map_err(|e| e.to_string())?;
        prop_assert!(decoded == &payload[..], "roundtrip payload mismatch");
        prop_assert!(consumed == payload.len() + FRAME_OVERHEAD, "consumed");
        Ok(())
    });
}

/// Every single-bit flip anywhere in a frame is rejected. (CRC32 detects
/// all single-bit errors outright; flips in the header are additionally
/// caught structurally — magic, version, length bounds.)
#[test]
fn prop_single_bit_corruption_is_caught() {
    check("frame-bitflip", 40, |g| {
        let payload = random_bytes(g, g.usize_in(1, 128));
        let mut wire = Vec::new();
        encode_frame_into(&payload, &mut wire);
        for byte in 0..wire.len() {
            for bit in 0..8u8 {
                let mut bad = wire.clone();
                bad[byte] ^= 1 << bit;
                match decode_frame(&bad) {
                    Err(_) => {}
                    Ok((got, _)) => {
                        return Err(format!(
                            "flip at byte {byte} bit {bit} went undetected \
                             (payload len {}, got len {})",
                            payload.len(),
                            got.len()
                        ))
                    }
                }
            }
        }
        Ok(())
    });
}

/// End-to-end bitwise roundtrip: a real `GradEncoder` submission in every
/// wire format, split over S ∈ {1, 2, 4} shards, framed, decoded, and
/// compared against the original payload *view* bit for bit — plus the
/// byte-accounting invariant (`wire_bytes` survives the trip).
#[test]
fn prop_submit_roundtrips_bitwise_across_formats_and_shards() {
    check("submit-roundtrip", 60, |g| {
        let dim = g.usize_in(8, 200);
        let wire_fmt = match g.rng.below(4) {
            0 => WireFormat::Dense,
            1 => WireFormat::parse(&format!("topk:{}", g.usize_in(1, dim))).unwrap(),
            2 => WireFormat::Int8,
            _ => WireFormat::parse(&format!("topk+int8:{}", g.usize_in(1, dim))).unwrap(),
        };
        for shards in [1usize, 2, 4] {
            let layout = ShardLayout::new(dim, shards);
            let mut enc = GradEncoder::new(wire_fmt.clone(), dim, layout.shards());
            let grad = g.vec_f32(dim, 1.5);
            let mut payloads = Vec::new();
            enc.encode(&grad, &layout, &mut payloads);
            let mut msg_buf = Vec::new();
            let mut frame = Vec::new();
            for (s, range) in layout.ranges().enumerate() {
                encode_submit_into(
                    s as u32,
                    9,
                    3,
                    0.25,
                    &payloads[s],
                    range.clone(),
                    &mut msg_buf,
                );
                frame.clear();
                encode_frame_into(&msg_buf, &mut frame);
                let (framed, consumed) = decode_frame(&frame).map_err(|e| e.to_string())?;
                prop_assert!(consumed == frame.len(), "partial consume");
                let msg = Msg::decode(framed).map_err(|e| e.to_string())?;
                let Msg::SubmitGrad {
                    shard,
                    seq,
                    base_version,
                    loss,
                    grad: got,
                } = msg
                else {
                    return Err("decoded to a non-submit message".into());
                };
                prop_assert!(shard == s as u32, "shard id");
                prop_assert!(seq == 9 && base_version == 3, "header fields");
                prop_assert!(loss.to_bits() == 0.25f32.to_bits(), "loss bits");
                // Bitwise view equivalence on the shard's slice.
                let n = range.len();
                let mut want = vec![0.0f32; n];
                payloads[s].view(range.clone()).add_to(&mut want);
                let mut have = vec![0.0f32; n];
                got.view(0..n).add_to(&mut have);
                for (i, (a, b)) in want.iter().zip(&have).enumerate() {
                    prop_assert!(
                        a.to_bits() == b.to_bits(),
                        "{wire_fmt} S={shards} shard {s} coord {i}: {a} vs {b}"
                    );
                }
                prop_assert!(
                    payloads[s].wire_bytes(n) == got.wire_bytes(n),
                    "{wire_fmt} S={shards}: wire_bytes changed across the trip"
                );
            }
        }
        Ok(())
    });
}

/// Truncating a *message* payload at every offset is a typed error too
/// (the frame layer passes a clean payload, the message layer still never
/// trusts lengths it has not checked).
#[test]
fn prop_msg_truncation_is_typed() {
    check("msg-truncation", 40, |g| {
        let dim = g.usize_in(4, 64);
        let layout = ShardLayout::new(dim, 1);
        let mut enc = GradEncoder::new(WireFormat::Dense, dim, 1);
        let grad = g.vec_f32(dim, 1.0);
        let mut payloads = Vec::new();
        enc.encode(&grad, &layout, &mut payloads);
        let mut msg_buf = Vec::new();
        encode_submit_into(0, 0, 0, 0.0, &payloads[0], 0..dim, &mut msg_buf);
        for cut in 0..msg_buf.len() {
            match Msg::decode(&msg_buf[..cut]) {
                Err(WireError::Truncated { .. }) => {}
                Err(other) => {
                    return Err(format!("cut={cut}: unexpected error {other:?}"))
                }
                Ok(_) => return Err(format!("cut={cut}: truncated message decoded")),
            }
        }
        Ok(())
    });
}
