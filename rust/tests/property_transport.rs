//! Property tests over the transport frame + message codec (mini-proptest
//! harness; see `util::proptest` — the offline image has no proptest or
//! fuzzing crates).
//!
//! The contracts under test (ISSUE 4 satellite):
//! - arbitrary-bytes fuzz never panics, and every failed decode is a
//!   *typed* error — not a hang, not a silently wrong payload;
//! - truncation at every byte offset is rejected;
//! - single-bit corruption anywhere in a frame is caught (CRC32 or a
//!   structural check);
//! - encode→decode roundtrips bitwise for every `ShardGrad` variant across
//!   the wire formats and shard counts S ∈ {1, 2, 4};
//! - (ISSUE 6) frame streams fragmented at every byte boundary and
//!   interleaved across connections decode identically to the
//!   unfragmented stream, and a slow-loris client trickling one byte per
//!   tick is evicted by the reactor's heartbeat timeout without stalling
//!   the other connections.

use hybrid_sgd::coordinator::compress::{GradEncoder, ShardGrad, WireFormat};
use hybrid_sgd::coordinator::server::{Reply, ShardEvent, ShardMsg};
use hybrid_sgd::coordinator::{ShardLayout, SnapshotCell};
use hybrid_sgd::prop_assert;
use hybrid_sgd::transport::frame::{
    decode_frame, encode_frame_into, FrameError, FrameReader, FRAME_OVERHEAD,
};
use hybrid_sgd::transport::msg::{encode_submit_into, Msg, WireError};
use hybrid_sgd::transport::{Frontend, FrontendKind, NetOptions, TcpTransport, Transport};
use hybrid_sgd::util::proptest::{check, Gen};
use std::io::{Read, Write};
use std::sync::atomic::AtomicBool;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn random_bytes(g: &mut Gen, len: usize) -> Vec<u8> {
    (0..len).map(|_| g.rng.below(256) as u8).collect()
}

/// Arbitrary bytes through the frame decoder: never a panic, never a
/// false positive (the probability of random bytes carrying a valid magic,
/// version, bounded length *and* matching CRC is ~2⁻⁶⁴; with the seeded
/// generator this is deterministic, so a flake cannot occur).
#[test]
fn prop_frame_decoder_survives_arbitrary_bytes() {
    check("frame-fuzz", 300, |g| {
        let len = g.usize_in(0, 2048);
        let mut buf = random_bytes(g, len);
        match decode_frame(&buf) {
            Err(
                FrameError::Truncated { .. }
                | FrameError::BadMagic { .. }
                | FrameError::Version { .. }
                | FrameError::TooLarge { .. }
                | FrameError::Corrupt { .. },
            ) => {}
            Ok(_) => return Err("random bytes decoded as a valid frame".into()),
        }
        // The streaming reader survives the same garbage (poisoning
        // itself rather than looping or panicking).
        let mut r = FrameReader::new();
        r.feed(&buf);
        let mut payload = Vec::new();
        for _ in 0..4 {
            match r.next_frame(&mut payload) {
                Ok(true) => return Err("garbage produced a frame".into()),
                Ok(false) => break,
                Err(_) => {} // typed, sticky
            }
        }
        // ...and arbitrary bytes through the message decoder never panic.
        buf.truncate(g.usize_in(0, len));
        match Msg::decode(&buf) {
            Err(
                WireError::Truncated { .. }
                | WireError::UnknownMsg(_)
                | WireError::UnknownPayload(_)
                | WireError::Invalid(_),
            ) => {}
            // A random first byte can hit a valid tag with trivially
            // consistent contents (e.g. Shutdown = one byte): fine, the
            // decode is still well-typed.
            Ok(_) => {}
        }
        Ok(())
    });
}

/// A valid frame truncated at *every* byte offset yields `Truncated` with
/// an honest `need > have`; never a payload.
#[test]
fn prop_truncation_rejected_at_every_offset() {
    check("frame-truncation", 60, |g| {
        let payload = random_bytes(g, g.usize_in(0, 256));
        let mut wire = Vec::new();
        encode_frame_into(&payload, &mut wire);
        for cut in 0..wire.len() {
            match decode_frame(&wire[..cut]) {
                Err(FrameError::Truncated { need, have }) => {
                    prop_assert!(have == cut, "have={have} at cut={cut}");
                    prop_assert!(need > cut, "need={need} not past cut={cut}");
                }
                other => {
                    return Err(format!("cut={cut}: expected Truncated, got {other:?}"))
                }
            }
        }
        let (decoded, consumed) = decode_frame(&wire).map_err(|e| e.to_string())?;
        prop_assert!(decoded == &payload[..], "roundtrip payload mismatch");
        prop_assert!(consumed == payload.len() + FRAME_OVERHEAD, "consumed");
        Ok(())
    });
}

/// Every single-bit flip anywhere in a frame is rejected. (CRC32 detects
/// all single-bit errors outright; flips in the header are additionally
/// caught structurally — magic, version, length bounds.)
#[test]
fn prop_single_bit_corruption_is_caught() {
    check("frame-bitflip", 40, |g| {
        let payload = random_bytes(g, g.usize_in(1, 128));
        let mut wire = Vec::new();
        encode_frame_into(&payload, &mut wire);
        for byte in 0..wire.len() {
            for bit in 0..8u8 {
                let mut bad = wire.clone();
                bad[byte] ^= 1 << bit;
                match decode_frame(&bad) {
                    Err(_) => {}
                    Ok((got, _)) => {
                        return Err(format!(
                            "flip at byte {byte} bit {bit} went undetected \
                             (payload len {}, got len {})",
                            payload.len(),
                            got.len()
                        ))
                    }
                }
            }
        }
        Ok(())
    });
}

/// End-to-end bitwise roundtrip: a real `GradEncoder` submission in every
/// wire format, split over S ∈ {1, 2, 4} shards, framed, decoded, and
/// compared against the original payload *view* bit for bit — plus the
/// byte-accounting invariant (`wire_bytes` survives the trip).
#[test]
fn prop_submit_roundtrips_bitwise_across_formats_and_shards() {
    check("submit-roundtrip", 60, |g| {
        let dim = g.usize_in(8, 200);
        let wire_fmt = match g.rng.below(4) {
            0 => WireFormat::Dense,
            1 => WireFormat::parse(&format!("topk:{}", g.usize_in(1, dim))).unwrap(),
            2 => WireFormat::Int8,
            _ => WireFormat::parse(&format!("topk+int8:{}", g.usize_in(1, dim))).unwrap(),
        };
        for shards in [1usize, 2, 4] {
            let layout = ShardLayout::new(dim, shards);
            let mut enc = GradEncoder::new(wire_fmt.clone(), dim, layout.shards());
            let grad = g.vec_f32(dim, 1.5);
            let mut payloads = Vec::new();
            enc.encode(&grad, &layout, &mut payloads);
            let mut msg_buf = Vec::new();
            let mut frame = Vec::new();
            for (s, range) in layout.ranges().enumerate() {
                encode_submit_into(
                    s as u32,
                    9,
                    3,
                    0.25,
                    &payloads[s],
                    range.clone(),
                    &mut msg_buf,
                )
                .map_err(|e| e.to_string())?;
                frame.clear();
                encode_frame_into(&msg_buf, &mut frame);
                let (framed, consumed) = decode_frame(&frame).map_err(|e| e.to_string())?;
                prop_assert!(consumed == frame.len(), "partial consume");
                let msg = Msg::decode(framed).map_err(|e| e.to_string())?;
                let Msg::SubmitGrad {
                    shard,
                    seq,
                    base_version,
                    loss,
                    grad: got,
                } = msg
                else {
                    return Err("decoded to a non-submit message".into());
                };
                prop_assert!(shard == s as u32, "shard id");
                prop_assert!(seq == 9 && base_version == 3, "header fields");
                prop_assert!(loss.to_bits() == 0.25f32.to_bits(), "loss bits");
                // Bitwise view equivalence on the shard's slice.
                let n = range.len();
                let mut want = vec![0.0f32; n];
                payloads[s].view(range.clone()).add_to(&mut want);
                let mut have = vec![0.0f32; n];
                got.view(0..n).add_to(&mut have);
                for (i, (a, b)) in want.iter().zip(&have).enumerate() {
                    prop_assert!(
                        a.to_bits() == b.to_bits(),
                        "{wire_fmt} S={shards} shard {s} coord {i}: {a} vs {b}"
                    );
                }
                prop_assert!(
                    payloads[s].wire_bytes(n) == got.wire_bytes(n),
                    "{wire_fmt} S={shards}: wire_bytes changed across the trip"
                );
            }
        }
        Ok(())
    });
}

/// The reactor's read path sees frames in arbitrary fragments, interleaved
/// across many connections sharing one loop. Model that exactly: K streams
/// of valid frames, delivered one byte at a time round-robin (every frame
/// therefore crosses every possible fragmentation boundary) and again in
/// random-sized chunks — each stream's decoded payload sequence must match
/// its unfragmented reference bit for bit, with no cross-stream bleed.
#[test]
fn prop_fragmented_interleaved_streams_decode_identically() {
    check("frame-fragmentation", 60, |g| {
        const K: usize = 3;
        let mut wires: Vec<Vec<u8>> = Vec::with_capacity(K);
        let mut reference: Vec<Vec<Vec<u8>>> = Vec::with_capacity(K);
        for _ in 0..K {
            let frames = g.usize_in(1, 5);
            let mut wire = Vec::new();
            let mut payloads = Vec::new();
            for _ in 0..frames {
                let payload = random_bytes(g, g.usize_in(0, 300));
                encode_frame_into(&payload, &mut wire);
                payloads.push(payload);
            }
            wires.push(wire);
            reference.push(payloads);
        }
        for chunked in [false, true] {
            let mut readers: Vec<FrameReader> = (0..K).map(|_| FrameReader::new()).collect();
            let mut got: Vec<Vec<Vec<u8>>> = vec![Vec::new(); K];
            let mut offsets = vec![0usize; K];
            let mut payload = Vec::new();
            loop {
                let mut progressed = false;
                for k in 0..K {
                    let remaining = wires[k].len() - offsets[k];
                    if remaining == 0 {
                        continue;
                    }
                    progressed = true;
                    let take = if chunked {
                        g.usize_in(1, 7).min(remaining)
                    } else {
                        1
                    };
                    readers[k].feed(&wires[k][offsets[k]..offsets[k] + take]);
                    offsets[k] += take;
                    while readers[k].next_frame(&mut payload).map_err(|e| e.to_string())? {
                        got[k].push(payload.clone());
                    }
                }
                if !progressed {
                    break;
                }
            }
            for k in 0..K {
                prop_assert!(
                    got[k] == reference[k],
                    "stream {k} (chunked={chunked}): fragmented decode diverged \
                     ({} frames vs {} expected)",
                    got[k].len(),
                    reference[k].len()
                );
            }
        }
        Ok(())
    });
}

/// Read one whole message from a raw blocking socket.
fn read_raw_msg(stream: &mut std::net::TcpStream, reader: &mut FrameReader) -> Msg {
    let mut chunk = [0u8; 1024];
    let mut payload = Vec::new();
    loop {
        if reader.next_frame(&mut payload).expect("clean frame stream") {
            return Msg::decode(&payload).expect("valid message");
        }
        let n = stream.read(&mut chunk).expect("socket read");
        assert!(n > 0, "connection closed while expecting a message");
        reader.feed(&chunk[..n]);
    }
}

/// A slow-loris client trickles one byte of a heartbeat frame per 25 ms —
/// never completing a frame inside the 400 ms liveness window — while a
/// healthy worker keeps submitting on the same reactor. The loris must be
/// evicted by the frame-based liveness timeout (announced as an elastic
/// `Leave`), and the healthy worker's submit→ack flow must never stall.
#[test]
fn slow_loris_is_evicted_without_stalling_other_connections() {
    let dim = 8usize;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = format!("{}", listener.local_addr().unwrap());
    let layout = ShardLayout::new(dim, 1);
    let (grad_tx, grad_rx) = mpsc::channel::<ShardEvent>();
    let (rtx0, rrx0) = mpsc::channel::<Reply>();
    let (rtx1, rrx1) = mpsc::channel::<Reply>();
    let reply_txs = [rtx0, rtx1];
    let cells = vec![Arc::new(SnapshotCell::new(vec![0.0f32; dim]))];
    let stop = Arc::new(AtomicBool::new(false));
    let net = NetOptions {
        hb_interval: Duration::from_millis(50),
        hb_timeout: Duration::from_millis(400),
        connect_timeout: Duration::from_secs(5),
        reconnect_attempts: 0,
        ..NetOptions::default()
    };
    let frontend = Frontend::start(
        FrontendKind::Reactor,
        listener,
        layout,
        vec![grad_tx],
        cells,
        vec![rrx0, rrx1],
        vec![false, false],
        Arc::clone(&stop),
        net.clone(),
        true, // elastic: eviction is announced as a Leave
        None,
        None,
    )
    .expect("start reactor");
    let notify = frontend.reply_notifier().expect("reactor notifier");

    // Echo shard stub: ack every submission, forward membership events.
    let (leave_tx, leave_rx) = mpsc::channel::<u32>();
    let echo = std::thread::spawn(move || {
        let mut version = 0u64;
        while let Ok(ev) = grad_rx.recv() {
            match ev {
                ShardEvent::Grad(ShardMsg { worker, .. }) => {
                    version += 1;
                    let _ = reply_txs[worker].send(Reply::Updated { shard: 0, version });
                    notify(worker);
                }
                ShardEvent::Leave { worker } => {
                    let _ = leave_tx.send(worker as u32);
                }
                _ => {}
            }
        }
    });

    // The loris attaches first (taking slot 0), then trickles.
    let mut loris = std::net::TcpStream::connect(&addr).unwrap();
    let mut loris_reader = FrameReader::new();
    {
        let mut msg_buf = Vec::new();
        let mut frame_buf = Vec::new();
        Msg::Hello {
            worker: hybrid_sgd::transport::msg::WORKER_UNASSIGNED,
            shards: 0,
            wire: "dense".to_string(),
        }
        .encode_into(&mut msg_buf)
        .unwrap();
        encode_frame_into(&msg_buf, &mut frame_buf);
        loris.write_all(&frame_buf).unwrap();
    }
    let loris_worker = match read_raw_msg(&mut loris, &mut loris_reader) {
        Msg::Welcome { worker, .. } => worker,
        other => panic!("loris expected Welcome, got {other:?}"),
    };
    let attach_at = Instant::now();
    let loris_thread = std::thread::spawn(move || {
        // A 22-byte heartbeat frame at 1 byte / 25 ms completes a frame
        // every ~550 ms: always slower than the 400 ms liveness window.
        let mut msg_buf = Vec::new();
        let mut frame_buf = Vec::new();
        Msg::Heartbeat { seq: 1 }.encode_into(&mut msg_buf).unwrap();
        encode_frame_into(&msg_buf, &mut frame_buf);
        let mut i = 0usize;
        loop {
            if loris.write_all(&frame_buf[i..=i]).is_err() {
                return; // evicted: the reactor closed the socket
            }
            i = (i + 1) % frame_buf.len();
            std::thread::sleep(Duration::from_millis(25));
        }
    });

    // Healthy worker on the same reactor: submits must keep flowing the
    // whole time the loris is being starved out.
    let mut healthy = TcpTransport::connect(&addr, "dense", net).expect("healthy connect");
    let grad = ShardGrad::Dense(Arc::new(vec![0.5f32; dim]));
    let mut submit_ok = |t: &mut TcpTransport, worker: usize| {
        t.submit(
            0,
            ShardMsg {
                worker,
                base_version: 0,
                loss: 0.1,
                grad: grad.clone(),
            },
        )
        .expect("submit");
        matches!(
            t.recv_reply(Duration::from_secs(2)).expect("ack"),
            Reply::Updated { shard: 0, .. }
        )
    };
    let healthy_worker = healthy.attach_info().worker;
    let deadline = Instant::now() + Duration::from_secs(8);
    let evicted_at = loop {
        assert!(
            submit_ok(&mut healthy, healthy_worker),
            "healthy ack stalled while the loris starved"
        );
        match leave_rx.try_recv() {
            Ok(w) => {
                assert_eq!(w, loris_worker, "the loris is the one evicted");
                break Instant::now();
            }
            Err(_) => assert!(Instant::now() < deadline, "loris never evicted within 8 s"),
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    let starved_for = evicted_at - attach_at;
    assert!(
        starved_for >= Duration::from_millis(200),
        "evicted suspiciously early ({starved_for:?}) — liveness must allow \
         the full heartbeat window"
    );
    // The healthy connection survived the eviction: more acks after it.
    for _ in 0..5 {
        assert!(submit_ok(&mut healthy, healthy_worker));
    }
    drop(healthy);
    loris_thread.join().unwrap();
    frontend.shutdown();
    echo.join().unwrap();
}

/// Truncating a *message* payload at every offset is a typed error too
/// (the frame layer passes a clean payload, the message layer still never
/// trusts lengths it has not checked).
#[test]
fn prop_msg_truncation_is_typed() {
    check("msg-truncation", 40, |g| {
        let dim = g.usize_in(4, 64);
        let layout = ShardLayout::new(dim, 1);
        let mut enc = GradEncoder::new(WireFormat::Dense, dim, 1);
        let grad = g.vec_f32(dim, 1.0);
        let mut payloads = Vec::new();
        enc.encode(&grad, &layout, &mut payloads);
        let mut msg_buf = Vec::new();
        encode_submit_into(0, 0, 0, 0.0, &payloads[0], 0..dim, &mut msg_buf)
            .map_err(|e| e.to_string())?;
        for cut in 0..msg_buf.len() {
            match Msg::decode(&msg_buf[..cut]) {
                Err(WireError::Truncated { .. }) => {}
                Err(other) => {
                    return Err(format!("cut={cut}: unexpected error {other:?}"))
                }
                Ok(_) => return Err(format!("cut={cut}: truncated message decoded")),
            }
        }
        Ok(())
    });
}
