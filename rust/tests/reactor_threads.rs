//! Server thread count is O(1) in the number of connections (ISSUE 6
//! acceptance): the reactor frontend serves every worker from one thread,
//! where the legacy threaded frontend spawned reader/writer/reply-pump
//! threads per connection. Asserted via `/proc/self/status`'s `Threads:`
//! line, so this test is Linux-only (the file is empty elsewhere).

#![cfg(target_os = "linux")]

use hybrid_sgd::coordinator::server::{Reply, ShardEvent};
use hybrid_sgd::coordinator::{ShardLayout, SnapshotCell};
use hybrid_sgd::transport::frame::{encode_frame_into, FrameReader};
use hybrid_sgd::transport::msg::{Msg, WORKER_UNASSIGNED};
use hybrid_sgd::transport::{Frontend, FrontendKind, NetOptions};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::AtomicBool;
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Current thread count of this process, from /proc/self/status.
fn threads_now() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").expect("read /proc/self/status");
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .expect("Threads: line")
        .trim()
        .parse()
        .expect("thread count")
}

/// Attach one raw client (no client-side threads: this test counts only
/// what the *server* spawns) and return the connected socket.
fn raw_attach(addr: &str) -> TcpStream {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).ok();
    let mut msg_buf = Vec::new();
    let mut frame_buf = Vec::new();
    Msg::Hello {
        worker: WORKER_UNASSIGNED,
        shards: 0,
        wire: "dense".to_string(),
    }
    .encode_into(&mut msg_buf)
    .expect("encode hello");
    encode_frame_into(&msg_buf, &mut frame_buf);
    stream.write_all(&frame_buf).expect("send hello");
    let mut reader = FrameReader::new();
    let mut chunk = [0u8; 1024];
    let mut payload = Vec::new();
    loop {
        if reader.next_frame(&mut payload).expect("clean stream") {
            match Msg::decode(&payload).expect("valid message") {
                Msg::Welcome { .. } => return stream,
                Msg::Shutdown | Msg::Evict { .. } => panic!("attach refused"),
                _ => {}
            }
        } else {
            let n = stream.read(&mut chunk).expect("read");
            assert!(n > 0, "closed during attach");
            reader.feed(&chunk[..n]);
        }
    }
}

#[test]
fn reactor_thread_count_is_constant_in_connections() {
    const SLOTS: usize = 32;
    let dim = 16usize;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = format!("{}", listener.local_addr().unwrap());
    let layout = ShardLayout::new(dim, 1);
    let (grad_tx, _grad_rx) = mpsc::channel::<ShardEvent>();
    let mut reply_txs = Vec::with_capacity(SLOTS);
    let mut reply_rxs = Vec::with_capacity(SLOTS);
    for _ in 0..SLOTS {
        let (tx, rx) = mpsc::channel::<Reply>();
        reply_txs.push(tx);
        reply_rxs.push(rx);
    }
    let cells = vec![Arc::new(SnapshotCell::new(vec![0.0f32; dim]))];
    let stop = Arc::new(AtomicBool::new(false));
    // Long heartbeat windows: nothing must churn (or evict) mid-count.
    let net = NetOptions {
        hb_interval: Duration::from_secs(60),
        hb_timeout: Duration::from_secs(300),
        connect_timeout: Duration::from_secs(5),
        reconnect_attempts: 0,
        ..NetOptions::default()
    };
    let frontend = Frontend::start(
        FrontendKind::Reactor,
        listener,
        layout,
        vec![grad_tx],
        cells,
        reply_rxs,
        vec![false; SLOTS],
        Arc::clone(&stop),
        net,
        false,
        None,
        None,
    )
    .expect("start reactor");

    let before = threads_now();
    let mut conns = Vec::with_capacity(SLOTS);
    for _ in 0..4 {
        conns.push(raw_attach(&addr));
    }
    assert_eq!(frontend.ever_joined(), 4);
    let at_4 = threads_now();
    for _ in 4..SLOTS {
        conns.push(raw_attach(&addr));
    }
    assert_eq!(frontend.active_conns(), SLOTS);
    let at_32 = threads_now();

    assert_eq!(
        at_4, before,
        "server spawned threads for the first 4 connections"
    );
    assert_eq!(
        at_32, before,
        "server thread count grew with connections ({before} -> {at_32} at {SLOTS} conns)"
    );

    drop(conns);
    frontend.shutdown();
    drop(reply_txs);
}
