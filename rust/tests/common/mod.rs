//! Shared workload fixtures for the coordinator integration suites
//! (`coordinator_integration` and `sim_integration` compile this module
//! each; keeping it single-sourced stops the two suites drifting onto
//! different workloads).
#![allow(dead_code)] // each test binary uses a subset

use hybrid_sgd::coordinator::worker::BatchSource;
use hybrid_sgd::coordinator::{EvalSet, RunInputs};
use hybrid_sgd::data::{random_cluster, Batcher, Dataset};
use hybrid_sgd::engine::{factory, GradEngine};
use hybrid_sgd::native::MlpEngine;
use hybrid_sgd::util::rng::Pcg64;
use std::sync::Arc;

pub const DIMS: [usize; 3] = [20, 32, 10];

pub struct Fixture {
    pub train_set: Arc<Dataset>,
    pub test: EvalSet,
    pub probe: EvalSet,
    pub init: Vec<f32>,
}

/// Random-cluster MLP workload, fully determined by `seed`.
pub fn fixture(seed: u64) -> Fixture {
    hybrid_sgd::util::logging::set_level(hybrid_sgd::util::logging::Level::Off);
    let mut rng = Pcg64::seeded(seed);
    let spec = random_cluster::ClusterSpec {
        n_samples: 1000,
        ..Default::default()
    };
    let full = random_cluster::generate(&spec, &mut rng);
    let (train_set, test_set) = full.split(0.8, &mut rng);
    let test = EvalSet::from_dataset(&test_set, 200, &mut rng);
    let probe = EvalSet::from_dataset(&train_set, 200, &mut rng);
    let init = MlpEngine::init_params(&DIMS, &mut rng);
    Fixture {
        train_set: Arc::new(train_set),
        test,
        probe,
        init,
    }
}

/// Workload plumbing shared by virtual and real-clock runs.
pub fn inputs_for(fx: &Fixture, workers: usize) -> RunInputs<'_> {
    let batch = 16;
    let dims: Vec<usize> = DIMS.to_vec();
    let dims2 = dims.clone();
    let data_shards = fx.train_set.shard_indices(workers);
    let train_arc = Arc::clone(&fx.train_set);
    RunInputs {
        worker_engine: factory(move || {
            Ok(Box::new(MlpEngine::new(dims.clone(), batch)) as Box<dyn GradEngine>)
        }),
        eval_engine: factory(move || {
            Ok(Box::new(MlpEngine::new(dims2.clone(), 50)) as Box<dyn GradEngine>)
        }),
        batch_source: Arc::new(move |id| {
            // `% len`: elastic joiners (ids past the launch complement)
            // reuse a launch worker's data shard; launch ids unaffected.
            Box::new(Batcher::new(
                Arc::clone(&train_arc),
                data_shards[id % data_shards.len()].clone(),
                batch,
                Pcg64::new(11, id as u64),
            )) as Box<dyn BatchSource>
        }),
        init_params: &fx.init,
        test: &fx.test,
        train_probe: &fx.probe,
    }
}

/// Engine that errors on its 5th gradient — the failure-injection probe
/// used by both the threaded and the simulated engine-failure tests.
pub struct FlakyEngine {
    calls: u32,
    inner: MlpEngine,
}

impl FlakyEngine {
    pub fn new() -> FlakyEngine {
        FlakyEngine {
            calls: 0,
            inner: MlpEngine::new(DIMS.to_vec(), 16),
        }
    }
}

impl Default for FlakyEngine {
    fn default() -> Self {
        FlakyEngine::new()
    }
}

impl GradEngine for FlakyEngine {
    fn param_count(&self) -> usize {
        self.inner.param_count()
    }
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }
    fn grad(&mut self, p: &[f32], x: &[f32], y: &[i32], g: &mut [f32]) -> anyhow::Result<f32> {
        self.calls += 1;
        anyhow::ensure!(self.calls < 5, "injected failure");
        self.inner.grad(p, x, y, g)
    }
    fn eval(&mut self, p: &[f32], x: &[f32], y: &[i32]) -> anyhow::Result<(f64, usize)> {
        self.inner.eval(p, x, y)
    }
}

/// [`inputs_for`] with every worker on a [`FlakyEngine`] (each fails after
/// 4 gradients).
pub fn flaky_inputs(fx: &Fixture, workers: usize) -> RunInputs<'_> {
    let dims2: Vec<usize> = DIMS.to_vec();
    let data_shards = fx.train_set.shard_indices(workers);
    let train_arc = Arc::clone(&fx.train_set);
    RunInputs {
        worker_engine: factory(move || Ok(Box::new(FlakyEngine::new()) as Box<dyn GradEngine>)),
        eval_engine: factory(move || {
            Ok(Box::new(MlpEngine::new(dims2.clone(), 50)) as Box<dyn GradEngine>)
        }),
        batch_source: Arc::new(move |id| {
            Box::new(Batcher::new(
                Arc::clone(&train_arc),
                data_shards[id].clone(),
                16,
                Pcg64::new(13, id as u64),
            )) as Box<dyn BatchSource>
        }),
        init_params: &fx.init,
        test: &fx.test,
        train_probe: &fx.probe,
    }
}
