//! Integration tests across runtime + coordinator using the real AOT
//! artifacts. Skipped (with a message) when `artifacts/` has not been built.

use hybrid_sgd::coordinator::{train, DelayModel, EvalSet, Policy, RunInputs, Schedule, TrainConfig};
use hybrid_sgd::data::{random_cluster, Batcher};
use hybrid_sgd::engine::GradEngine;
use hybrid_sgd::native::MlpEngine;
use hybrid_sgd::runtime::{engine_factories, init_params, Manifest, UpdateOp, XlaEngine};
use hybrid_sgd::util::rng::Pcg64;
use std::sync::Arc;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

/// The JAX MLP and the native Rust MLP share the flat parameter layout
/// (per layer: W [in×out] row-major, then b). Gradients must agree.
#[test]
fn xla_mlp_grad_matches_native_backprop() {
    let Some(man) = manifest() else { return };
    let mut rng = Pcg64::seeded(7);
    let entry = man.model("mlp").unwrap();
    let params = init_params(entry, &mut rng).unwrap();

    let batch = 8;
    let mut xla = XlaEngine::new(&man, "mlp", Some(batch), "jnp", false).unwrap();
    let mut native = MlpEngine::new(vec![20, 64, 64, 10], batch);
    assert_eq!(xla.param_count(), native.param_count());

    let mut x = vec![0.0f32; batch * 20];
    rng.fill_normal(&mut x, 1.0);
    let y: Vec<i32> = (0..batch).map(|i| (i % 10) as i32).collect();

    let mut gx = vec![0.0f32; params.len()];
    let mut gn = vec![0.0f32; params.len()];
    let lx = xla.grad(&params, &x, &y, &mut gx).unwrap();
    let ln = native.grad(&params, &x, &y, &mut gn).unwrap();

    assert!((lx - ln).abs() < 1e-4, "loss mismatch: xla={lx} native={ln}");
    let mut max_diff = 0.0f32;
    for (a, b) in gx.iter().zip(&gn) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-3, "grad mismatch: max |Δ| = {max_diff}");
}

#[test]
fn xla_pallas_variant_matches_jnp_variant() {
    let Some(man) = manifest() else { return };
    let mut rng = Pcg64::seeded(8);
    let entry = man.model("mlp").unwrap();
    let params = init_params(entry, &mut rng).unwrap();
    let batch = 32;
    let mut jnp = XlaEngine::new(&man, "mlp", Some(batch), "jnp", false).unwrap();
    let mut pal = XlaEngine::new(&man, "mlp", Some(batch), "pallas", false).unwrap();
    let mut x = vec![0.0f32; batch * 20];
    rng.fill_normal(&mut x, 1.0);
    let y: Vec<i32> = (0..batch).map(|i| (i % 10) as i32).collect();
    let mut g1 = vec![0.0f32; params.len()];
    let mut g2 = vec![0.0f32; params.len()];
    let l1 = jnp.grad(&params, &x, &y, &mut g1).unwrap();
    let l2 = pal.grad(&params, &x, &y, &mut g2).unwrap();
    assert!((l1 - l2).abs() < 1e-4);
    for (a, b) in g1.iter().zip(&g2) {
        assert!((a - b).abs() < 1e-3, "pallas/jnp grads differ: {a} vs {b}");
    }
}

#[test]
fn xla_eval_reports_sane_metrics() {
    let Some(man) = manifest() else { return };
    let mut rng = Pcg64::seeded(9);
    let entry = man.model("mlp").unwrap();
    let params = init_params(entry, &mut rng).unwrap();
    let mut eval = XlaEngine::new(&man, "mlp", None, "jnp", true).unwrap();
    let b = eval.eval_batch_size();
    assert_eq!(b, 100);
    let mut x = vec![0.0f32; b * 20];
    rng.fill_normal(&mut x, 1.0);
    let y: Vec<i32> = (0..b).map(|i| (i % 10) as i32).collect();
    let (sum_loss, correct) = eval.eval(&params, &x, &y).unwrap();
    // fresh glorot init → loss near ln(10), accuracy near chance
    let mean = sum_loss / b as f64;
    assert!((1.8..3.0).contains(&mean), "mean loss {mean}");
    assert!(correct <= b);
}

#[test]
fn update_op_applies_scaled_subtraction() {
    let Some(man) = manifest() else { return };
    for variant in ["jnp", "pallas"] {
        let mut op = UpdateOp::new(&man, "mlp", variant).unwrap();
        let n = op.param_count;
        let mut params: Vec<f32> = (0..n).map(|i| i as f32 * 1e-3).collect();
        let grads: Vec<f32> = (0..n).map(|i| ((i % 7) as f32) - 3.0).collect();
        let expect: Vec<f32> = params
            .iter()
            .zip(&grads)
            .map(|(p, g)| p - 0.01 * g)
            .collect();
        op.apply(&mut params, &grads, 0.01).unwrap();
        for (a, b) in params.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-5, "{variant}: {a} vs {b}");
        }
    }
}

/// Full-stack smoke: hybrid training through real XLA executables learns the
/// paper's random-cluster task.
#[test]
fn full_stack_hybrid_training_learns() {
    let Some(_) = manifest() else { return };
    let mut rng = Pcg64::seeded(10);
    let spec = random_cluster::ClusterSpec {
        n_samples: 1500,
        ..Default::default()
    };
    let full = random_cluster::generate(&spec, &mut rng);
    let (train_set, test_set) = full.split(0.8, &mut rng);

    let man = Manifest::load("artifacts").unwrap();
    let entry = man.model("mlp").unwrap();
    let init = init_params(entry, &mut rng).unwrap();
    let (worker_f, eval_f) = engine_factories("artifacts", "mlp", 16, "jnp").unwrap();

    let test = EvalSet::from_dataset(&test_set, 200, &mut rng);
    let probe = EvalSet::from_dataset(&train_set, 200, &mut rng);
    let train_arc = Arc::new(train_set);
    let shards = train_arc.shard_indices(3);
    let inputs = RunInputs {
        worker_engine: worker_f,
        eval_engine: eval_f,
        batch_source: Arc::new(move |id| {
            Box::new(Batcher::new(
                Arc::clone(&train_arc),
                shards[id].clone(),
                16,
                Pcg64::new(99, id as u64),
            )) as Box<dyn hybrid_sgd::coordinator::worker::BatchSource>
        }),
        init_params: &init,
        test: &test,
        train_probe: &probe,
    };
    let mut cfg = TrainConfig::quick(
        Policy::Hybrid {
            schedule: Schedule::Step { step: 100 },
            strict: false,
        },
        3,
        3.0,
    );
    cfg.lr = 0.05;
    cfg.delay = DelayModel::none();
    let m = train(&cfg, &inputs).unwrap();
    assert!(m.gradients_total > 10, "only {} gradients", m.gradients_total);
    let first = m.test_acc.v[0];
    let last = *m.test_acc.v.last().unwrap();
    assert!(
        last > first + 15.0,
        "no learning through the XLA stack: {first}% → {last}%"
    );
}
