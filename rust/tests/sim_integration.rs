//! Acceptance tests for the deterministic virtual-time simulator:
//! bitwise reproducibility, the sub-second port of the paper's headline
//! async/sync/hybrid comparison, fault-injection behaviour, and the
//! checkpoint save→resume golden trace.

mod common;

use common::{fixture, inputs_for};
use hybrid_sgd::coordinator::checkpoint::Checkpoint;
use hybrid_sgd::coordinator::sim::{simulate, FaultPlan, Scenario, Simulation};
use hybrid_sgd::coordinator::{DelayModel, Policy, RunInputs, RunMetrics, Schedule, TrainConfig};
use std::time::Duration;

fn scenario(spec: &str) -> Scenario {
    Scenario::parse(spec).expect("scenario spec")
}

/// Acceptance: the same seed + scenario spec yields bitwise-identical
/// RunMetrics (updates, per-shard counts, loss trace) across two runs.
#[test]
fn same_seed_and_scenario_is_bitwise_identical() {
    let fx = fixture(1);
    let inputs = inputs_for(&fx, 4);
    let spec = "workers=4 shards=2 policy=hybrid:step:50 secs=2 seed=7 grad-ms=5 \
                delay-frac=0.5 delay-std=0.25 \
                faults=crash:3@1,restart:3@1.4,slow:*@0.5..0.8*4,drop:0@0..2:0.2,dup:1@0..2:0.2,stall:1@0.6..0.7";
    let a = simulate(&scenario(spec), &inputs).unwrap();
    let b = simulate(&scenario(spec), &inputs).unwrap();
    assert_eq!(a, b, "virtual-time runs must replay bitwise from the seed");
    assert!(a.gradients_total > 0);
    assert_eq!(a.shards, 2);

    // A different seed takes a different trajectory (delay draws differ).
    let other = simulate(
        &scenario(&spec.replace("seed=7", "seed=8")),
        &inputs,
    )
    .unwrap();
    assert_ne!(a, other, "seed must steer the run");
}

/// Acceptance: the paper's headline comparison — async vs sync vs hybrid
/// under injected worker delays — ported to the virtual clock. Runs
/// deterministically and completes in well under a second of wall time
/// (release; a relaxed budget guards debug builds).
#[test]
fn headline_comparison_virtual_and_subsecond() {
    let fx = fixture(2);
    let inputs = inputs_for(&fx, 4);
    let wall = std::time::Instant::now();

    let mut results: Vec<(Policy, RunMetrics)> = Vec::new();
    for policy in [
        Policy::Async,
        Policy::Sync,
        Policy::Hybrid {
            schedule: Schedule::Step { step: 50 },
            strict: false,
        },
    ] {
        let mut scn = scenario(
            "workers=4 secs=2 seed=5 grad-ms=5 delay-frac=0.5 delay-std=0.1",
        );
        scn.train.policy = policy.clone();
        let m = simulate(&scn, &inputs).unwrap();
        let last = *m.test_acc.v.last().unwrap();
        assert!(last > 20.0, "{policy}: final acc {last}");
        results.push((policy, m));
    }
    let elapsed = wall.elapsed();
    let budget = if cfg!(debug_assertions) {
        Duration::from_secs(10)
    } else {
        Duration::from_secs(1)
    };
    assert!(
        elapsed < budget,
        "virtual comparison took {elapsed:?} (budget {budget:?}) — did a real sleep sneak in?"
    );

    let async_m = &results[0].1;
    let sync_m = &results[1].1;
    let hybrid_m = &results[2].1;
    // structural shape of the paper's comparison
    assert_eq!(async_m.updates_total, async_m.gradients_total);
    assert!(sync_m.updates_total < async_m.updates_total / 2);
    assert!(hybrid_m.flushes > 0);
    assert!(
        hybrid_m.mean_staleness <= async_m.mean_staleness,
        "hybrid staleness {} > async {}",
        hybrid_m.mean_staleness,
        async_m.mean_staleness
    );
}

/// A shard stall slows the run but preserves the lockstep invariant: every
/// shard still aggregates the identical arrival sequence.
#[test]
fn shard_stall_delays_but_preserves_lockstep() {
    let fx = fixture(3);
    let inputs = inputs_for(&fx, 3);
    let clean = simulate(
        &scenario("workers=3 shards=3 policy=async secs=1.5 grad-ms=5"),
        &inputs,
    )
    .unwrap();
    let stalled = simulate(
        &scenario("workers=3 shards=3 policy=async secs=1.5 grad-ms=5 faults=stall:1@0.2..0.9"),
        &inputs,
    )
    .unwrap();
    assert!(
        stalled.gradients_total < clean.gradients_total,
        "stall did not slow the run: {} vs {}",
        stalled.gradients_total,
        clean.gradients_total
    );
    let (min, max) = (
        *stalled.per_shard_updates.iter().min().unwrap(),
        *stalled.per_shard_updates.iter().max().unwrap(),
    );
    assert_eq!(min, max, "stall broke lockstep: {:?}", stalled.per_shard_updates);
}

/// Dropped submissions lose gradients; duplicated submissions inflate the
/// server-side arrival count. Both are seeded and observable.
#[test]
fn dropped_and_duplicated_submissions_are_accounted() {
    let fx = fixture(4);
    let inputs = inputs_for(&fx, 3);
    let base = "workers=3 policy=async secs=1.5 grad-ms=5";
    let clean = simulate(&scenario(base), &inputs).unwrap();

    let mut sim = Simulation::new(
        &scenario(&format!("{base} faults=drop:*@0..1.5:0.4")),
        &inputs,
    )
    .unwrap();
    sim.run_until(Duration::from_secs(2)).unwrap();
    let dropped = sim.faults_dropped();
    let lossy = sim.finish().unwrap();
    assert!(dropped > 0, "no submissions dropped");
    assert!(
        lossy.gradients_total < clean.gradients_total,
        "drops did not reduce arrivals: {} vs {}",
        lossy.gradients_total,
        clean.gradients_total
    );

    let mut sim = Simulation::new(
        &scenario(&format!("{base} faults=dup:*@0..1.5:0.5")),
        &inputs,
    )
    .unwrap();
    sim.run_until(Duration::from_secs(2)).unwrap();
    let duplicated = sim.faults_duplicated();
    let dupped = sim.finish().unwrap();
    assert!(duplicated > 0, "no submissions duplicated");
    assert!(
        dupped.gradients_total > clean.gradients_total,
        "duplicates did not inflate arrivals: {} vs {}",
        dupped.gradients_total,
        clean.gradients_total
    );
}

/// ISSUE-5 regression, the scenario that motivated elastic membership: a
/// hybrid run whose schedule has shifted to full sync (strict, K = W) plus
/// a permanent worker loss. With static membership the barrier can never
/// be met again — the survivors block forever and the step budget is
/// unreachable within any virtual-time deadline. With `elastic=on` the
/// crash *evicts* the worker from the barrier denominator, the buffered
/// contributions flush, and every survivor completes its full budget.
#[test]
fn full_sync_hybrid_survives_permanent_worker_loss_only_with_elastic() {
    let fx = fixture(41);
    let inputs = inputs_for(&fx, 3);
    // hybrid-strict:const:3 at W=3 *is* the sync barrier; secs=6 is the
    // virtual-time deadline — ample for 40 steps at 5 ms if the run is
    // live, unreachable if the barrier stalls. The crash lands at ~round
    // 10, well inside every worker's 40-step budget.
    let stalled_spec = "workers=3 policy=hybrid-strict:const:3 secs=6 grad-ms=5 steps=40 \
                        faults=crash:1@0.05";
    let stalled = simulate(&scenario(stalled_spec), &inputs).unwrap();
    assert!(
        stalled.per_worker_grads[0] < 40 && stalled.per_worker_grads[2] < 40,
        "static membership should stall the survivors at the barrier: {:?}",
        stalled.per_worker_grads
    );

    let elastic = simulate(
        &scenario(&format!("{stalled_spec} elastic=on")),
        &inputs,
    )
    .unwrap();
    assert_eq!(
        (elastic.per_worker_grads[0], elastic.per_worker_grads[2]),
        (40, 40),
        "elastic membership must let the survivors finish their budget: {:?}",
        elastic.per_worker_grads
    );
    assert!(
        elastic.updates_total > stalled.updates_total,
        "renormalized barrier should keep applying updates: {} vs {}",
        elastic.updates_total,
        stalled.updates_total
    );
    // Membership telemetry: the crash eviction (3 → 2) plus the
    // survivors' clean budget-spent departures.
    assert!(elastic.membership_epochs >= 1);
    assert_eq!(elastic.membership.v[0], 2.0, "first transition is the eviction");
    let last = *elastic.membership.v.last().unwrap();
    assert!(last < 2.0, "departures must show in the trajectory");
    // And the chaos run replays bitwise like every other scenario.
    let again = simulate(
        &scenario(&format!("{stalled_spec} elastic=on")),
        &inputs,
    )
    .unwrap();
    assert_eq!(elastic, again);
}

/// Elastic mode with zero churn is *bitwise inert*: no membership events
/// ever fire, so the entire `RunMetrics` — loss curves, trajectories,
/// counters, final parameters, membership telemetry — is identical to the
/// static run, and `elastic=off` is bitwise the default pipeline. The
/// golden guard that the membership machinery changes nothing until
/// someone actually leaves.
#[test]
fn elastic_without_churn_preserves_the_static_training_trace() {
    let fx = fixture(42);
    let inputs = inputs_for(&fx, 4);
    let base = "workers=4 shards=2 policy=hybrid:step:40 secs=2 seed=3 grad-ms=5 \
                delay-frac=0.5 delay-std=0.1";
    let default_run = simulate(&scenario(base), &inputs).unwrap();
    let explicit_off = simulate(&scenario(&format!("{base} elastic=off")), &inputs).unwrap();
    assert_eq!(
        default_run, explicit_off,
        "elastic=off must be bitwise the default pipeline"
    );
    assert_eq!(default_run.membership_epochs, 0);
    assert!(default_run.membership.is_empty());

    let elastic_on = simulate(&scenario(&format!("{base} elastic=on")), &inputs).unwrap();
    assert_eq!(
        elastic_on, default_run,
        "churn-free elastic must be bitwise identical to the static run"
    );
}

/// Crashing a worker under sync starves the barrier (the known sync
/// fragility the paper argues against); a restart resumes progress.
#[test]
fn sync_barrier_starves_on_crash_and_recovers_on_restart() {
    let fx = fixture(5);
    let inputs = inputs_for(&fx, 3);
    let crashed = simulate(
        &scenario("workers=3 policy=sync secs=2 grad-ms=5 faults=crash:0@0.5"),
        &inputs,
    )
    .unwrap();
    let recovered = simulate(
        &scenario("workers=3 policy=sync secs=2 grad-ms=5 faults=crash:0@0.5,restart:0@1"),
        &inputs,
    )
    .unwrap();
    assert!(
        recovered.updates_total > crashed.updates_total,
        "restart did not recover the barrier: {} vs {}",
        recovered.updates_total,
        crashed.updates_total
    );
    // async shrugs the same crash off
    let async_crashed = simulate(
        &scenario("workers=3 policy=async secs=2 grad-ms=5 faults=crash:0@0.5"),
        &inputs,
    )
    .unwrap();
    assert!(async_crashed.updates_total > crashed.updates_total);
}

/// Golden trace for checkpoint save → resume: pausing a simulated run
/// mid-flight to save (and re-load) a checkpoint does not perturb it — the
/// resumed run's RunMetrics are bitwise identical to an uninterrupted
/// run's — and legacy metas without a `shards` key restore as shard=1 with
/// identical parameters.
#[test]
fn checkpoint_mid_run_save_resume_reproduces_golden_trace() {
    let fx = fixture(6);
    let inputs = inputs_for(&fx, 4);
    let spec = "workers=4 shards=2 policy=hybrid:step:40 secs=2 seed=9 grad-ms=5 \
                delay-frac=0.5 delay-std=0.1";

    // Uninterrupted reference trace.
    let reference = simulate(&scenario(spec), &inputs).unwrap();

    // Same scenario, paused mid-run to checkpoint.
    let mut sim = Simulation::new(&scenario(spec), &inputs).unwrap();
    sim.run_until(Duration::from_millis(900)).unwrap();
    let ck = sim.checkpoint("mlp");
    assert_eq!(ck.shards, 2);
    assert_eq!(ck.params, sim.assembled_params());
    assert_eq!(ck.ps_version, sim.ps_version());

    let dir = std::env::temp_dir().join("hsgd_sim_ckpt_golden");
    let _ = std::fs::remove_dir_all(&dir);
    let (_, meta_path) = ck.save(&dir, "mid").unwrap();
    let loaded = Checkpoint::load(&dir, "mid").unwrap();
    assert_eq!(loaded, ck, "checkpoint round-trip");

    // Legacy meta (pre-shard format, no `shards` key) restores as shard=1
    // with bitwise-identical parameters.
    std::fs::write(
        &meta_path,
        format!(
            r#"{{"model":"mlp","policy":"{}","ps_version":{},"param_count":{}}}"#,
            ck.policy,
            ck.ps_version,
            ck.params.len()
        ),
    )
    .unwrap();
    let legacy = Checkpoint::load(&dir, "mid").unwrap();
    assert_eq!(legacy.shards, 1);
    assert_eq!(legacy.params, ck.params);

    // Resume: the save/load pause must not have perturbed the simulation.
    let resumed = sim.finish().unwrap();
    assert_eq!(
        resumed, reference,
        "mid-run checkpoint save/resume diverged from the uninterrupted run"
    );

    // Warm start from the checkpoint: the flat layout is shard-count
    // independent, so restoring under S=1 and S=2 yields the identical
    // metric trace (lockstep invariant), and each is itself reproducible.
    let warm_inputs = RunInputs {
        init_params: &loaded.params,
        ..inputs_for(&fx, 4)
    };
    let warm_spec_s1 = "workers=4 shards=1 policy=hybrid:step:40 secs=1 seed=3 grad-ms=5";
    let warm_spec_s2 = "workers=4 shards=2 policy=hybrid:step:40 secs=1 seed=3 grad-ms=5";
    let w1 = simulate(&scenario(warm_spec_s1), &warm_inputs).unwrap();
    let w1b = simulate(&scenario(warm_spec_s1), &warm_inputs).unwrap();
    let w2 = simulate(&scenario(warm_spec_s2), &warm_inputs).unwrap();
    assert_eq!(w1, w1b);
    assert_eq!(w1.test_loss, w2.test_loss, "shard count changed the math");
    assert_eq!(w1.test_acc, w2.test_acc);
    assert_eq!(w1.updates_total, w2.updates_total);
}

/// The scenario DSL round-trips through Display, so a logged scenario line
/// is directly replayable.
#[test]
fn scenario_line_replays_identically() {
    let fx = fixture(7);
    let inputs = inputs_for(&fx, 3);
    let scn = scenario(
        "workers=3 shards=2 policy=hybrid:step:30 secs=1 seed=2 grad-ms=5 \
         delay-frac=0.5 delay-std=0.05 faults=slow:*@0.2..0.6*3,crash:2@0.8",
    );
    let logged = scn.to_string();
    let replay = scenario(&logged);
    let a = simulate(&scn, &inputs).unwrap();
    let b = simulate(&replay, &inputs).unwrap();
    assert_eq!(a, b, "Display → parse round-trip changed the run");
}

/// Acceptance (wire formats): a compressed scenario — top-k 1% with error
/// feedback, under the full PR-2 fault cocktail — replays bitwise from its
/// seed, including the new bytes-on-wire counters and compression-ratio
/// series. The deterministic tie-breaking in top-k selection is what makes
/// this hold on every platform.
#[test]
fn compressed_sim_golden_trace_is_bitwise_reproducible() {
    let fx = fixture(4);
    let inputs = inputs_for(&fx, 4);
    let spec = "workers=4 shards=2 policy=hybrid:step:50 secs=2 seed=7 grad-ms=5 \
                delay-frac=0.5 delay-std=0.25 compress=topk:0.01 \
                faults=crash:3@1,restart:3@1.4,slow:*@0.5..0.8*4,drop:0@0..2:0.2,dup:1@0..2:0.2,stall:1@0.6..0.7";
    let a = simulate(&scenario(spec), &inputs).unwrap();
    let b = simulate(&scenario(spec), &inputs).unwrap();
    assert_eq!(a, b, "compressed virtual-time runs must replay bitwise");
    assert!(a.gradients_total > 0);
    assert!(a.bytes_sent > 0);
    // MLP fixture has 1002 parameters → k = 10 → 80 B/submission vs 4008 B
    // dense: the ≥50× acceptance bound holds end-to-end, faults included.
    assert!(
        a.wire_compression() >= 50.0,
        "topk:0.01 should cut bytes ≥50×, got {:.1}x",
        a.wire_compression()
    );
    // Drop faults lose bytes in flight; dup faults re-deliver them.
    assert!(a.bytes_received > 0);
    // The ratio series is sampled on the eval grid and replays with the rest.
    assert!(!a.compression_ratio.is_empty());
    // Display → parse round-trip preserves the compressed scenario.
    let replayed = simulate(&scenario(&scenario(spec).to_string()), &inputs).unwrap();
    assert_eq!(a, replayed, "compress= clause lost in the DSL round-trip");
}

/// Acceptance (dense golden trace): `compress=dense` is bitwise identical
/// to a scenario that never mentions compression — same metrics, and the
/// byte counters confirm nothing was compressed (sent == dense-equivalent).
#[test]
fn compress_dense_is_bitwise_identical_to_default_pipeline() {
    let fx = fixture(5);
    let inputs = inputs_for(&fx, 3);
    let base = "workers=3 shards=2 policy=hybrid:step:40 secs=1.5 seed=3 grad-ms=5 \
                delay-frac=0.5 delay-std=0.1";
    let implicit = simulate(&scenario(base), &inputs).unwrap();
    let explicit =
        simulate(&scenario(&format!("{base} compress=dense")), &inputs).unwrap();
    assert_eq!(
        implicit, explicit,
        "compress=dense must reproduce the default pipeline bitwise"
    );
    assert_eq!(implicit.bytes_sent, implicit.bytes_dense_equiv);
    assert_eq!(implicit.wire_compression(), 1.0);
}

/// Compressed training still learns: error feedback keeps top-k runs
/// converging on the fixture workload, and int8 stays within quantization
/// noise of dense.
#[test]
fn compressed_runs_still_learn() {
    let fx = fixture(6);
    let inputs = inputs_for(&fx, 4);
    for fmt in ["topk:0.25", "int8", "topk+int8:0.25"] {
        let m = simulate(
            &scenario(&format!(
                "workers=4 policy=hybrid:step:50 secs=2 seed=5 grad-ms=5 compress={fmt}"
            )),
            &inputs,
        )
        .unwrap();
        let first = m.test_acc.v[0];
        let last = *m.test_acc.v.last().unwrap();
        assert!(
            last > first + 10.0,
            "{fmt}: accuracy did not improve ({first:.1} → {last:.1})"
        );
    }
}

/// TrainConfig built by the experiments layer drives the simulator the
/// same way the DSL does (the CLI `--sim` path).
#[test]
fn trainconfig_scenario_equivalence() {
    let fx = fixture(8);
    let inputs = inputs_for(&fx, 3);
    let tc = TrainConfig {
        policy: Policy::Async,
        workers: 3,
        lr: 0.05,
        duration: Duration::from_secs(1),
        delay: DelayModel::none(),
        seed: 0,
        eval_interval: Duration::from_millis(500),
        k_max: None,
        compute_floor: Duration::ZERO,
        shards: 1,
        wire: hybrid_sgd::coordinator::WireFormat::Dense,
        steps: None,
        elastic: false,
        min_quorum: 1,
        stream: None,
        aggregate: hybrid_sgd::coordinator::AggregateMode::Mean,
        partition: hybrid_sgd::data::Partition::Iid,
        trace: None,
        param_dtype: hybrid_sgd::coordinator::ParamDtype::F32,
    };
    let via_struct = Scenario {
        train: tc,
        grad_time: Duration::from_millis(5),
        faults: FaultPlan::default(),
    };
    let via_dsl = scenario("workers=3 policy=async secs=1 seed=0 lr=0.05 grad-ms=5");
    let a = simulate(&via_struct, &inputs).unwrap();
    let b = simulate(&via_dsl, &inputs).unwrap();
    assert_eq!(a, b);
}

/// Acceptance (ISSUE 7): a long-horizon sim run with a `--metrics-stream`
/// sink replays its live series bit-for-bit from the JSONL file, the sink
/// never perturbs the run, and `--metrics-cap` bounds the in-memory series
/// while the file keeps the full record.
#[test]
fn metrics_stream_replays_a_sim_run_bitwise_with_bounded_memory() {
    use hybrid_sgd::coordinator::{replay_stream, MetricsStream};
    use std::sync::Arc;

    let fx = fixture(11);
    let inputs = inputs_for(&fx, 3);
    let dir = std::env::temp_dir().join("hsgd_sim_stream_test");
    std::fs::create_dir_all(&dir).unwrap();

    // Long horizon: 60 virtual seconds at the 500 ms eval interval is
    // ~120 samples per series — enough for a 16-sample window to bite.
    let base = "workers=3 shards=2 policy=hybrid:step:50 secs=60 seed=9 grad-ms=50 lr=0.05";
    let reference = simulate(&scenario(base), &inputs).unwrap();
    assert!(
        reference.test_loss.len() > 64,
        "horizon too short to exercise the cap ({} samples)",
        reference.test_loss.len()
    );

    // Uncapped: the observer changes nothing, and the file replays bitwise.
    let path = dir.join("uncapped.jsonl");
    let mut scn = scenario(base);
    scn.train.stream = Some(Arc::new(MetricsStream::create(&path).unwrap()));
    let streamed = simulate(&scn, &inputs).unwrap();
    assert_eq!(streamed, reference, "the stream sink must not perturb the run");
    let replayed = replay_stream(&path).unwrap();
    assert_eq!(replayed.train_loss, reference.train_loss);
    assert_eq!(replayed.test_loss, reference.test_loss);
    assert_eq!(replayed.test_acc, reference.test_acc);
    assert_eq!(replayed.compression_ratio, reference.compression_ratio);
    assert_eq!(replayed.membership, reference.membership);

    // Capped: in-memory series stay inside the amortised 2×cap window...
    let path = dir.join("capped.jsonl");
    let mut scn = scenario(base);
    scn.train.stream = Some(Arc::new(
        MetricsStream::create(&path).unwrap().with_cap(16),
    ));
    let capped = simulate(&scn, &inputs).unwrap();
    assert!(
        capped.test_loss.len() < 32,
        "cap did not bound the in-memory series ({} samples)",
        capped.test_loss.len()
    );
    // ...and the window holds the *newest* samples.
    assert_eq!(
        capped.test_loss.v.last().map(|v| v.to_bits()),
        reference.test_loss.v.last().map(|v| v.to_bits())
    );
    // ...while the file still replays the complete history.
    let replayed = replay_stream(&path).unwrap();
    assert_eq!(replayed.test_loss, reference.test_loss);
    assert_eq!(replayed.train_loss, reference.train_loss);
}

/// Acceptance for the gradient-lifecycle flight recorder (`--trace`):
/// tracing is pure observation, so a traced run's metrics equal the
/// untraced run's bitwise, and the same seeded scenario exports
/// byte-identical Chrome traces across runs (virtual timestamps only —
/// no wall-clock read can leak into the export).
#[test]
fn traced_sim_exports_byte_identical_chrome_traces() {
    use hybrid_sgd::util::trace::{chrome_trace_json, TraceRing};
    use std::sync::Arc;

    let fx = fixture(9);
    let inputs = inputs_for(&fx, 4);
    let spec = "workers=4 shards=2 policy=hybrid:step:50 secs=2 seed=7 grad-ms=5 \
                delay-frac=0.5 delay-std=0.25 elastic=on \
                faults=crash:3@1,restart:3@1.4,stall:1@0.6..0.7";
    let untraced = simulate(&scenario(spec), &inputs).unwrap();

    let run_traced = || {
        let ring = Arc::new(TraceRing::new(1 << 15));
        let mut scn = scenario(spec);
        scn.train.trace = Some(Arc::clone(&ring));
        let m = simulate(&scn, &inputs).unwrap();
        (m, chrome_trace_json(&ring.drain()))
    };
    let (m1, json1) = run_traced();
    let (m2, json2) = run_traced();
    assert_eq!(m1, untraced, "tracing must not perturb the run");
    assert_eq!(
        json1, json2,
        "same seeded scenario must export byte-identical traces"
    );

    // The export actually covers the lifecycle: worker-side spans, the
    // shard-side apply, and the flush instants the hybrid policy emits.
    for stage in ["compute", "encode", "wire", "apply", "flush"] {
        assert!(
            json1.contains(&format!("\"name\":\"{stage}\"")),
            "stage `{stage}` never appears in the export"
        );
    }
    // The fault plan's crash surfaces as a membership transition.
    assert!(
        json1.contains("\"name\":\"leave\""),
        "crash at t=1 must record a leave instant"
    );

    // The offline analyzer in the CLI consumes this same document; its
    // core invariant (recorded == retained + dropped) holds here too.
    let doc = hybrid_sgd::util::json::parse(&json1).unwrap();
    let recorded = doc.get("recorded").and_then(|v| v.as_f64()).unwrap();
    let retained = doc.get("retained").and_then(|v| v.as_f64()).unwrap();
    let dropped = doc.get("dropped").and_then(|v| v.as_f64()).unwrap();
    assert_eq!(recorded, retained + dropped);
    assert!(retained > 0.0, "a traced run must retain events");
}
