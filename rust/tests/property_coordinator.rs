//! Property tests over the coordinator invariants (mini-proptest harness;
//! see `util::proptest` — the offline image has no proptest crate).

use hybrid_sgd::coordinator::params::ParamStore;
use hybrid_sgd::coordinator::{Aggregator, Outcome, Policy, Schedule, ShardedAggregator};
use hybrid_sgd::engine::GradEngine;
use hybrid_sgd::native::QuadraticEngine;
use hybrid_sgd::prop_assert;
use hybrid_sgd::util::proptest::check;

fn random_schedule(g: &mut hybrid_sgd::util::proptest::Gen) -> Schedule {
    match g.rng.below(5) {
        0 => Schedule::Constant {
            k: g.usize_in(1, 16),
        },
        1 => Schedule::Step {
            step: g.usize_in(1, 400),
        },
        2 => Schedule::Linear {
            rate: g.f64_in(0.0001, 0.1),
        },
        3 => Schedule::Exponential {
            step: g.usize_in(10, 400),
            growth: g.f64_in(1.1, 3.0),
        },
        _ => Schedule::Sigmoid {
            mid: g.f64_in(10.0, 1000.0),
            scale: g.f64_in(1.0, 300.0),
        },
    }
}

/// K(n) is monotone non-decreasing and within [1, k_max] for every schedule.
#[test]
fn prop_schedules_monotone_bounded() {
    check("schedules-monotone", 200, |g| {
        let s = random_schedule(g);
        let k_max = g.usize_in(1, 32);
        let mut prev = 0usize;
        let mut n = 0u64;
        for _ in 0..200 {
            n += g.rng.below(50);
            let k = s.k(n, k_max);
            prop_assert!((1..=k_max).contains(&k), "{s}: k={k} out of [1,{k_max}]");
            prop_assert!(k >= prev, "{s}: not monotone at n={n}");
            prev = k;
        }
        Ok(())
    });
}

/// Conservation: every gradient fed to any policy is either applied (alone
/// or inside a flush) or still buffered; after drain, applied == arrivals.
#[test]
fn prop_no_gradient_lost() {
    check("no-gradient-lost", 100, |g| {
        let workers = g.usize_in(1, 12);
        let dim = g.usize_in(1, 40);
        let policy = match g.rng.below(3) {
            0 => Policy::Async,
            1 => Policy::Sync,
            _ => Policy::Hybrid {
                schedule: random_schedule(g),
                strict: g.bool(),
            },
        };
        let mut agg = Aggregator::new(policy.clone(), dim, workers);
        let mut ps = ParamStore::new(vec![0.0; dim], 0.01);
        let n = g.usize_in(1, 300);
        let mut accounted = 0u64;
        for _ in 0..n {
            let grad = g.vec_f32(dim, 1.0);
            let worker = g.usize_in(0, workers - 1);
            let v = ps.version();
            match agg.on_gradient(&mut ps, &grad, worker, v, 1.0) {
                Outcome::AppliedNow => accounted += 1,
                Outcome::Flushed { count, .. } => accounted += count as u64,
                Outcome::Buffered | Outcome::BufferedBlocked => {}
            }
        }
        accounted += agg.drain(&mut ps) as u64;
        prop_assert!(
            accounted == n as u64,
            "{policy}: accounted {accounted} != arrivals {n}"
        );
        Ok(())
    });
}

/// Sharded-store equivalence: for S ∈ {1, 2, 4} and every policy, driving
/// the sharded state machine with the same seeded gradient stream as the
/// unsharded `Aggregator` + `ParamStore` pair yields bitwise-identical
/// final parameters, the same update count and the same K — the invariant
/// that keeps the paper's sync/async/hybrid comparisons valid under the
/// sharded parameter server.
#[test]
fn prop_sharded_store_matches_unsharded_bitwise() {
    use hybrid_sgd::coordinator::AdaptiveConfig;
    check("sharded-equivalence", 60, |g| {
        let workers = g.usize_in(1, 8);
        let dim = g.usize_in(1, 48);
        let policy = match g.rng.below(4) {
            0 => Policy::Async,
            1 => Policy::Sync,
            2 => Policy::Hybrid {
                schedule: random_schedule(g),
                strict: g.bool(),
            },
            _ => Policy::HybridAdaptive {
                cfg: AdaptiveConfig {
                    window: g.usize_in(2, 40),
                    ..Default::default()
                },
                strict: false,
            },
        };
        let lr = 0.05f32;
        let init = g.vec_f32(dim, 1.0);
        let mut reference = Aggregator::new(policy.clone(), dim, workers);
        let mut ref_ps = ParamStore::new(init.clone(), lr);
        let mut sharded: Vec<ShardedAggregator> = [1usize, 2, 4]
            .iter()
            .map(|&s| ShardedAggregator::new(policy.clone(), &init, lr, workers, s))
            .collect();

        let n = g.usize_in(1, 250);
        for _ in 0..n {
            let grad = g.vec_f32(dim, 1.0);
            let worker = g.usize_in(0, workers - 1);
            let loss = g.f64_in(0.0, 4.0) as f32;
            let v = ref_ps.version();
            let out_ref = reference.on_gradient(&mut ref_ps, &grad, worker, v, loss);
            for m in sharded.iter_mut() {
                prop_assert!(m.version() == v, "{policy}: version drifted");
                let out = m.on_gradient(&grad, worker, v, loss);
                prop_assert!(
                    out == out_ref,
                    "{policy}: outcome diverged ({out:?} vs {out_ref:?})"
                );
                prop_assert!(
                    m.current_k() == reference.current_k(),
                    "{policy}: K diverged"
                );
            }
        }
        reference.drain(&mut ref_ps);
        for (m, s) in sharded.iter_mut().zip([1usize, 2, 4]) {
            m.drain();
            prop_assert!(
                m.version() == ref_ps.version(),
                "{policy} S={s}: update count {} != {}",
                m.version(),
                ref_ps.version()
            );
            let params = m.final_params();
            prop_assert!(
                params == ref_ps.theta(),
                "{policy} S={s}: final params not bitwise identical"
            );
        }
        Ok(())
    });
}

/// The smooth hybrid with K=1 is numerically identical to async for any
/// gradient stream.
#[test]
fn prop_hybrid_k1_equals_async() {
    check("hybrid-k1-async", 100, |g| {
        let dim = g.usize_in(1, 32);
        let n = g.usize_in(1, 120);
        let mut a = Aggregator::new(Policy::Async, dim, 4);
        let mut h = Aggregator::new(
            Policy::Hybrid {
                schedule: Schedule::Constant { k: 1 },
                strict: false,
            },
            dim,
            4,
        );
        let mut psa = ParamStore::new(vec![0.5; dim], 0.02);
        let mut psh = ParamStore::new(vec![0.5; dim], 0.02);
        for _ in 0..n {
            let grad = g.vec_f32(dim, 2.0);
            let w = g.usize_in(0, 3);
            let (va, vh) = (psa.version(), psh.version());
            a.on_gradient(&mut psa, &grad, w, va, 1.0);
            h.on_gradient(&mut psh, &grad, w, vh, 1.0);
        }
        prop_assert!(psa.version() == psh.version(), "version mismatch");
        for (x, y) in psa.theta().iter().zip(psh.theta()) {
            prop_assert!((x - y).abs() < 1e-6, "theta diverged: {x} vs {y}");
        }
        Ok(())
    });
}

/// A flush applies exactly the mean of the buffered gradients.
#[test]
fn prop_flush_is_mean() {
    check("flush-is-mean", 100, |g| {
        let dim = g.usize_in(1, 24);
        let k = g.usize_in(1, 10);
        let lr = 0.1f32;
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: Schedule::Constant { k },
                strict: false,
            },
            dim,
            k.max(2),
        );
        let mut ps = ParamStore::new(vec![0.0; dim], lr);
        let grads: Vec<Vec<f32>> = (0..k).map(|_| g.vec_f32(dim, 1.0)).collect();
        for (i, grad) in grads.iter().enumerate() {
            let v = ps.version();
            agg.on_gradient(&mut ps, grad, i % k.max(2), v, 1.0);
        }
        prop_assert!(ps.version() == 1, "expected exactly one flush");
        for j in 0..dim {
            let mean: f32 = grads.iter().map(|gr| gr[j]).sum::<f32>() / k as f32;
            let want = -lr * mean;
            prop_assert!(
                (ps.theta()[j] - want).abs() < 1e-5,
                "dim {j}: {} != {want}",
                ps.theta()[j]
            );
        }
        Ok(())
    });
}

/// On a convex quadratic, sequential hybrid aggregation converges for any
/// monotone schedule (the paper's §3 convexity setting).
#[test]
fn prop_hybrid_converges_on_quadratic() {
    check("hybrid-converges-quadratic", 60, |g| {
        let dim = g.usize_in(2, 16);
        let workers = g.usize_in(2, 6);
        let schedule = random_schedule(g);
        let mut target = vec![0.0f32; dim];
        g.rng.fill_normal(&mut target, 3.0);
        // guard against a pathological all-near-zero target
        target[0] += 2.0;
        let mut eng = QuadraticEngine::new(target.clone(), 1, 0.05, g.rng.next_u64());
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule,
                strict: false,
            },
            dim,
            workers,
        );
        let mut ps = ParamStore::new(vec![0.0; dim], 0.2);
        let mut grad = vec![0.0f32; dim];
        let d0: f64 = target
            .iter()
            .map(|&t| (t as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        for i in 0..800 {
            eng.grad(ps.theta(), &[], &[], &mut grad).unwrap();
            let v = ps.version();
            agg.on_gradient(&mut ps, &grad, i % workers, v, 1.0);
        }
        agg.drain(&mut ps);
        let d1: f64 = ps
            .theta()
            .iter()
            .zip(&target)
            .map(|(p, t)| ((p - t) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        prop_assert!(d1 < d0 * 0.5, "did not converge: {d0:.3} -> {d1:.3}");
        Ok(())
    });
}

/// The adaptive policy conserves gradients and keeps K within bounds while
/// staleness and loss vary arbitrarily.
#[test]
fn prop_adaptive_conserves_and_bounds_k() {
    use hybrid_sgd::coordinator::AdaptiveConfig;
    check("adaptive-conserves", 60, |g| {
        let workers = g.usize_in(2, 8);
        let dim = g.usize_in(1, 16);
        let mut agg = Aggregator::new(
            Policy::HybridAdaptive {
                cfg: AdaptiveConfig {
                    window: g.usize_in(2, 40),
                    ..Default::default()
                },
                strict: false,
            },
            dim,
            workers,
        );
        let mut ps = ParamStore::new(vec![0.0; dim], 0.01);
        let n = g.usize_in(1, 400);
        let mut accounted = 0u64;
        for _ in 0..n {
            let grad = g.vec_f32(dim, 1.0);
            let w = g.usize_in(0, workers - 1);
            let v = ps.version().saturating_sub(g.rng.below(4));
            let loss = g.f64_in(0.0, 5.0) as f32;
            match agg.on_gradient(&mut ps, &grad, w, v, loss) {
                Outcome::AppliedNow => accounted += 1,
                Outcome::Flushed { count, .. } => accounted += count as u64,
                _ => {}
            }
            prop_assert!(
                (1..=workers).contains(&agg.current_k()),
                "adaptive K out of bounds: {}",
                agg.current_k()
            );
        }
        accounted += agg.drain(&mut ps) as u64;
        prop_assert!(accounted == n as u64, "lost gradients: {accounted}/{n}");
        Ok(())
    });
}

/// Sync flushes only when every worker contributed, regardless of order.
#[test]
fn prop_sync_barrier_requires_all_workers() {
    check("sync-barrier", 100, |g| {
        let workers = g.usize_in(2, 10);
        let dim = 4;
        let mut agg = Aggregator::new(Policy::Sync, dim, workers);
        let mut ps = ParamStore::new(vec![0.0; dim], 0.1);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..10_000 {
            let w = g.usize_in(0, workers - 1);
            let grad = g.vec_f32(dim, 1.0);
            let v = ps.version();
            match agg.on_gradient(&mut ps, &grad, w, v, 1.0) {
                Outcome::Flushed {
                    distinct_workers, ..
                } => {
                    seen.insert(w);
                    prop_assert!(
                        distinct_workers == workers,
                        "flushed with {distinct_workers}/{workers} distinct workers"
                    );
                    prop_assert!(
                        seen.len() == workers,
                        "flush before all workers arrived ({}/{workers})",
                        seen.len()
                    );
                    return Ok(());
                }
                Outcome::BufferedBlocked => {
                    seen.insert(w);
                }
                o => prop_assert!(false, "unexpected outcome {o:?}"),
            }
        }
        Ok(())
    });
}

/// Satellite property (wire formats): `split_shards` of a compressed
/// gradient, applied per shard through the sparse wire path, matches the
/// whole-vector dense apply of the same compressed gradient — bitwise for
/// pure top-k, within quantization tolerance for int8 values — for
/// S ∈ {1, 2, 4} and every policy family.
#[test]
fn prop_compressed_split_matches_dense_apply() {
    use hybrid_sgd::coordinator::compress::{
        GradEncoder, KSpec, SparseGrad, TopKCompressor, WireFormat,
    };

    check("compressed-split-matches-dense", 30, |g| {
        let dim = g.usize_in(4, 48);
        let workers = g.usize_in(1, 4);
        let lr = g.f64_in(0.01, 0.2) as f32;
        let k = g.usize_in(1, dim);
        let policy = match g.rng.below(4) {
            0 => Policy::Async,
            1 => Policy::Sync,
            2 => Policy::Hybrid {
                schedule: random_schedule(g),
                strict: false,
            },
            _ => Policy::Hybrid {
                schedule: random_schedule(g),
                strict: true,
            },
        };
        let int8 = g.bool();
        let init = g.vec_f32(dim, 1.0);
        for shards in [1usize, 2, 4] {
            let mut dense_m =
                ShardedAggregator::new(policy.clone(), &init, lr, workers, shards);
            let mut wire_m =
                ShardedAggregator::new(policy.clone(), &init, lr, workers, shards);
            let layout = wire_m.layout().clone();
            let wire = if int8 {
                WireFormat::TopKInt8(KSpec::Count(k))
            } else {
                WireFormat::TopK(KSpec::Count(k))
            };
            let mut enc = GradEncoder::new(wire, dim, layout.shards());
            // A twin compressor replays the identical error-feedback stream
            // to produce the dense reference of every transmission.
            let mut twin = TopKCompressor::new(dim, k);
            let mut sg = SparseGrad::with_dim(dim);
            let mut payloads = Vec::new();
            let mut maxabs_seen = 0.0f32;
            for i in 0..40 {
                let grad = g.vec_f32(dim, 1.0);
                enc.encode(&grad, &layout, &mut payloads);
                twin.compress_into(&grad, &mut sg);
                // Dense reference of what actually went on the wire.
                let reference = if int8 {
                    let maxabs = sg.val.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    maxabs_seen = maxabs_seen.max(maxabs);
                    let scale = if maxabs == 0.0 { 1.0 } else { maxabs / 127.0 };
                    let mut d = vec![0.0f32; dim];
                    for (&ix, &v) in sg.idx.iter().zip(&sg.val) {
                        d[ix as usize] =
                            (v / scale).round().clamp(-127.0, 127.0) * scale;
                    }
                    d
                } else {
                    sg.to_dense()
                };
                let w = i % workers.max(1);
                let (vd, vw) = (dense_m.version(), wire_m.version());
                prop_assert!(vd == vw, "S={shards}: version diverged at arrival {i}");
                let out_d = dense_m.on_gradient(&reference, w, vd, 1.0);
                let out_w = wire_m.on_payload(&payloads, w, vw, 1.0);
                prop_assert!(
                    std::mem::discriminant(&out_d) == std::mem::discriminant(&out_w),
                    "S={shards}: outcome diverged at arrival {i}: {out_d:?} vs {out_w:?}"
                );
            }
            dense_m.drain();
            wire_m.drain();
            // The reference already bakes in the int8 rounding, so both
            // formats should agree to float-noise; the tolerance absorbs
            // the f32 associativity slack of the two apply orders.
            let tol = if int8 { 1e-4 * maxabs_seen.max(1.0) } else { 0.0 };
            for (i, (a, b)) in dense_m
                .final_params()
                .iter()
                .zip(wire_m.final_params().iter())
                .enumerate()
            {
                prop_assert!(
                    (a - b).abs() <= tol,
                    "S={shards} coord {i}: dense {a} vs wire {b} (tol {tol})"
                );
            }
        }
        Ok(())
    });
}

/// Satellite property (wire formats): the error-feedback residual stays
/// bounded — finite, and small relative to the gradient scale × dimension —
/// under the PR-2 fault cocktail (crashes, straggler bursts, drops, dups,
/// stalls) on the virtual-time simulator. A broken feedback loop would grow
/// the residual with the iteration count; draining feedback keeps it O(dim).
#[test]
fn prop_error_feedback_residual_bounded_under_faults() {
    use hybrid_sgd::coordinator::sim::{FaultPlan, Scenario, Simulation};
    use hybrid_sgd::coordinator::worker::BatchSource;
    use hybrid_sgd::coordinator::{
        DelayModel, EvalSet, KSpec, RunInputs, TrainConfig, WireFormat,
    };
    use hybrid_sgd::engine::factory;
    use std::sync::Arc;
    use std::time::Duration;

    struct NullSource;
    impl BatchSource for NullSource {
        fn next(&mut self) -> (&[f32], &[i32]) {
            (&[], &[])
        }
    }

    check("residual-bounded-under-faults", 20, |g| {
        let workers = g.usize_in(2, 5);
        let shards = g.usize_in(1, 3);
        let dim = g.usize_in(shards.max(4), 24);
        let secs = 2.0f64;
        let k = g.usize_in(1, (dim / 2).max(1));

        let mut clauses: Vec<String> = Vec::new();
        if g.bool() {
            clauses.push(format!(
                "crash:{}@{}",
                g.usize_in(0, workers - 1),
                g.f64_in(0.1, 1.0)
            ));
        }
        if g.bool() {
            let a = g.f64_in(0.0, 0.8);
            let b = a + g.f64_in(0.1, 1.0);
            clauses.push(format!("slow:*@{a}..{b}*{}", g.f64_in(1.5, 8.0)));
        }
        if g.bool() {
            clauses.push(format!("drop:*@0..{secs}:{}", g.f64_in(0.05, 0.5)));
        }
        if g.bool() {
            clauses.push(format!("dup:*@0..{secs}:{}", g.f64_in(0.05, 0.5)));
        }
        if g.bool() {
            let s = g.usize_in(0, shards - 1);
            let a = g.f64_in(0.0, 1.0);
            let b = a + g.f64_in(0.05, 0.5);
            clauses.push(format!("stall:{s}@{a}..{b}"));
        }
        let faults = FaultPlan::parse(&clauses.join(","))
            .map_err(|e| format!("fault parse: {e:#}"))?;

        let mut train = TrainConfig::quick(
            Policy::Hybrid {
                schedule: random_schedule(g),
                strict: false,
            },
            workers,
            secs,
        );
        train.shards = shards;
        train.seed = g.rng.next_u64();
        train.lr = 0.05;
        train.wire = WireFormat::TopK(KSpec::Count(k));
        train.delay = DelayModel {
            affected_fraction: g.f64_in(0.0, 1.0),
            mean: 0.0,
            std: g.f64_in(0.0, 0.05),
        };
        let scn = Scenario {
            train,
            grad_time: Duration::from_millis(20),
            faults,
        };

        let init = g.vec_f32(dim, 1.0);
        let eval = EvalSet {
            x: vec![0.0],
            y: vec![0],
            n: 1,
            x_dim: 1,
            y_dim: 1,
        };
        let target = vec![1.0f32; dim];
        let t2 = target.clone();
        let inputs = RunInputs {
            worker_engine: factory(move || {
                Ok(Box::new(QuadraticEngine::new(target.clone(), 1, 0.0, 0))
                    as Box<dyn GradEngine>)
            }),
            eval_engine: factory(move || {
                Ok(Box::new(QuadraticEngine::new(t2.clone(), 1, 0.0, 0)) as Box<dyn GradEngine>)
            }),
            batch_source: Arc::new(|_| Box::new(NullSource) as Box<dyn BatchSource>),
            init_params: &init,
            test: &eval,
            train_probe: &eval,
        };

        let mut sim =
            Simulation::new(&scn, &inputs).map_err(|e| format!("sim init: {e:#}"))?;
        // The quadratic's gradients are bounded by the init→target spread
        // (|g| ≲ 5). A draining residual rotates coordinates through the
        // top-k, so per-coord mass is O((dim/k)·|g|) and the L1 total stays
        // O(dim²·|g|/k); a broken feedback loop instead grows linearly with
        // the iteration count (~100 iterations/worker here) and overshoots.
        let bound = dim as f64 * dim as f64 * 5.0;
        let mut t = Duration::ZERO;
        let end = Duration::from_secs_f64(secs);
        while t < end {
            t += Duration::from_millis(250);
            sim.run_until(t).map_err(|e| format!("sim step: {e:#}"))?;
            for w in 0..workers {
                let r = sim
                    .worker_residual_l1(w)
                    .ok_or_else(|| "top-k run must expose a residual".to_string())?;
                prop_assert!(
                    r.is_finite() && r <= bound,
                    "worker {w}: residual L1 {r} out of bounds at {t:?} (faults `{}`)",
                    clauses.join(",")
                );
            }
        }
        Ok(())
    });
}

/// Under *any* seeded delay/fault scenario — crashes, straggler bursts,
/// dropped/duplicated submissions, shard stalls, random schedules — the
/// hybrid policy's aggregation mode is monotone per shard: once a shard's
/// threshold K(n) switches away from the asynchronous regime it never
/// reverts (the paper's Algorithm 1 threshold semantics), and arrivals
/// never run backwards. Sampled live from the virtual-time simulator.
#[test]
fn prop_hybrid_mode_monotone_under_any_fault_scenario() {
    use hybrid_sgd::coordinator::sim::{FaultPlan, Scenario, Simulation};
    use hybrid_sgd::coordinator::worker::BatchSource;
    use hybrid_sgd::coordinator::{DelayModel, EvalSet, RunInputs, TrainConfig};
    use hybrid_sgd::engine::factory;
    use std::sync::Arc;
    use std::time::Duration;

    struct NullSource;
    impl BatchSource for NullSource {
        fn next(&mut self) -> (&[f32], &[i32]) {
            (&[], &[])
        }
    }

    check("hybrid-monotone-under-faults", 30, |g| {
        let workers = g.usize_in(2, 6);
        let shards = g.usize_in(1, 3);
        let dim = g.usize_in(shards, 16);
        let secs = 2.0f64;
        let schedule = random_schedule(g);
        let strict = g.bool();

        // A random cocktail of fault clauses over valid worker/shard ids,
        // assembled in the same DSL users write.
        let mut clauses: Vec<String> = Vec::new();
        if g.bool() {
            clauses.push(format!(
                "crash:{}@{}",
                g.usize_in(0, workers - 1),
                g.f64_in(0.1, 1.5)
            ));
        }
        if g.bool() {
            let a = g.f64_in(0.0, 0.8);
            let b = a + g.f64_in(0.1, 1.0);
            clauses.push(format!("slow:*@{a}..{b}*{}", g.f64_in(1.5, 10.0)));
        }
        if g.bool() {
            clauses.push(format!("drop:*@0..{secs}:{}", g.f64_in(0.05, 0.5)));
        }
        if g.bool() {
            clauses.push(format!("dup:*@0..{secs}:{}", g.f64_in(0.05, 0.5)));
        }
        if g.bool() {
            let s = g.usize_in(0, shards - 1);
            let a = g.f64_in(0.0, 1.0);
            let b = a + g.f64_in(0.05, 0.5);
            clauses.push(format!("stall:{s}@{a}..{b}"));
        }
        let faults = FaultPlan::parse(&clauses.join(","))
            .map_err(|e| format!("fault parse: {e:#}"))?;

        let mut train = TrainConfig::quick(
            Policy::Hybrid { schedule, strict },
            workers,
            secs,
        );
        train.shards = shards;
        train.seed = g.rng.next_u64();
        train.lr = 0.05;
        train.delay = DelayModel {
            affected_fraction: g.f64_in(0.0, 1.0),
            mean: 0.0,
            std: g.f64_in(0.0, 0.05),
        };
        let scn = Scenario {
            train,
            grad_time: Duration::from_millis(20),
            faults,
        };

        let init = g.vec_f32(dim, 1.0);
        let eval = EvalSet {
            x: vec![0.0],
            y: vec![0],
            n: 1,
            x_dim: 1,
            y_dim: 1,
        };
        let target = vec![1.0f32; dim];
        let t2 = target.clone();
        let inputs = RunInputs {
            worker_engine: factory(move || {
                Ok(Box::new(QuadraticEngine::new(target.clone(), 1, 0.0, 0))
                    as Box<dyn GradEngine>)
            }),
            eval_engine: factory(move || {
                Ok(Box::new(QuadraticEngine::new(t2.clone(), 1, 0.0, 0)) as Box<dyn GradEngine>)
            }),
            batch_source: Arc::new(|_| Box::new(NullSource) as Box<dyn BatchSource>),
            init_params: &init,
            test: &eval,
            train_probe: &eval,
        };

        let mut sim =
            Simulation::new(&scn, &inputs).map_err(|e| format!("sim init: {e:#}"))?;
        let mut last_k = vec![0usize; sim.shard_count()];
        let mut last_arrivals = vec![0u64; sim.shard_count()];
        let mut t = Duration::ZERO;
        let end = Duration::from_secs_f64(secs);
        while t < end {
            t += Duration::from_millis(100);
            sim.run_until(t).map_err(|e| format!("sim step: {e:#}"))?;
            for s in 0..sim.shard_count() {
                let k = sim.current_k(s);
                prop_assert!(
                    k >= last_k[s],
                    "shard {s}: K reverted {} -> {k} at {t:?} (faults `{}`)",
                    last_k[s],
                    clauses.join(",")
                );
                let a = sim.arrivals(s);
                prop_assert!(a >= last_arrivals[s], "shard {s}: arrivals went backwards");
                last_k[s] = k;
                last_arrivals[s] = a;
            }
        }
        Ok(())
    });
}

/// Elastic-membership chaos property (ISSUE 5): under *arbitrary* seeded
/// join/leave/crash/restart scenarios, per shard —
/// 1. the threshold K never exceeds live membership (quorum-floored),
/// 2. K is monotone non-decreasing *within* a membership epoch (it may
///    only step down when a departure renormalizes the cap),
/// 3. arrivals never run backwards, and
/// 4. every accepted gradient is applied exactly once: at every quiescent
///    point `applied + buffered == arrivals` (no loss, no double-apply
///    across evictions), with the end-of-run drain flushing the rest.
#[test]
fn prop_elastic_membership_k_bounded_and_gradients_conserved() {
    use hybrid_sgd::coordinator::sim::{Scenario, Simulation};
    use hybrid_sgd::coordinator::worker::BatchSource;
    use hybrid_sgd::coordinator::{EvalSet, RunInputs};
    use hybrid_sgd::engine::factory;
    use std::sync::Arc;
    use std::time::Duration;

    struct NullSource;
    impl BatchSource for NullSource {
        fn next(&mut self) -> (&[f32], &[i32]) {
            (&[], &[])
        }
    }

    check("elastic-k-bounded-conserved", 25, |g| {
        let workers = g.usize_in(2, 5);
        let shards = g.usize_in(1, 3);
        let dim = g.usize_in(shards.max(4), 20);
        let secs = 2.0f64;
        let min_quorum = g.usize_in(1, 2);

        // Random membership churn plus the classic fault cocktail, in the
        // user-facing DSL. Worker-naming clauses stay within the launch
        // complement; joiners take appended slots.
        let mut clauses: Vec<String> = Vec::new();
        clauses.push(format!(
            "leave:{}@{}",
            g.usize_in(0, workers - 1),
            g.f64_in(0.1, 1.2)
        ));
        if g.bool() {
            clauses.push(format!("join:+{}@{}", g.usize_in(1, 2), g.f64_in(0.1, 1.5)));
        }
        if g.bool() {
            clauses.push(format!(
                "crash:{}@{}",
                g.usize_in(0, workers - 1),
                g.f64_in(0.1, 1.5)
            ));
        }
        if g.bool() {
            let w = g.usize_in(0, workers - 1);
            let t = g.f64_in(0.2, 1.0);
            clauses.push(format!("crash:{w}@{t}"));
            clauses.push(format!("restart:{w}@{}", t + g.f64_in(0.1, 0.8)));
        }
        if g.bool() {
            let s = g.usize_in(0, shards - 1);
            let a = g.f64_in(0.0, 1.0);
            let b = a + g.f64_in(0.05, 0.5);
            clauses.push(format!("stall:{s}@{a}..{b}"));
        }

        let spec = format!(
            "workers={workers} shards={shards} policy=hybrid{}:{} secs={secs} \
             seed={} grad-ms=20 lr=0.05 elastic=on quorum={min_quorum} faults={}",
            if g.bool() { "-strict" } else { "" },
            random_schedule(g),
            g.rng.below(1 << 20),
            clauses.join(","),
        );
        let scn = Scenario::parse(&spec).map_err(|e| format!("scenario `{spec}`: {e:#}"))?;

        let init = g.vec_f32(dim, 1.0);
        let eval = EvalSet {
            x: vec![0.0],
            y: vec![0],
            n: 1,
            x_dim: 1,
            y_dim: 1,
        };
        let target = vec![1.0f32; dim];
        let t2 = target.clone();
        let inputs = RunInputs {
            worker_engine: factory(move || {
                Ok(Box::new(QuadraticEngine::new(target.clone(), 1, 0.0, 0))
                    as Box<dyn GradEngine>)
            }),
            eval_engine: factory(move || {
                Ok(Box::new(QuadraticEngine::new(t2.clone(), 1, 0.0, 0)) as Box<dyn GradEngine>)
            }),
            batch_source: Arc::new(|_| Box::new(NullSource) as Box<dyn BatchSource>),
            init_params: &init,
            test: &eval,
            train_probe: &eval,
        };

        let mut sim =
            Simulation::new(&scn, &inputs).map_err(|e| format!("sim init `{spec}`: {e:#}"))?;
        let n_shards = sim.shard_count();
        let mut last_k = vec![0usize; n_shards];
        let mut last_epoch = vec![0u64; n_shards];
        let mut last_arrivals = vec![0u64; n_shards];
        let mut t = Duration::ZERO;
        let end = Duration::from_secs_f64(secs);
        while t < end {
            t += Duration::from_millis(100);
            sim.run_until(t).map_err(|e| format!("sim step: {e:#}"))?;
            for s in 0..n_shards {
                let k = sim.current_k(s);
                let live = sim.shard_live(s);
                let epoch = sim.shard_membership_epoch(s);
                let bound = live.max(min_quorum).max(1);
                prop_assert!(
                    k <= bound,
                    "shard {s}: K={k} exceeds live membership {live} \
                     (quorum {min_quorum}) at {t:?} (`{spec}`)"
                );
                prop_assert!(
                    epoch >= last_epoch[s],
                    "shard {s}: membership epoch went backwards (`{spec}`)"
                );
                if epoch == last_epoch[s] {
                    prop_assert!(
                        k >= last_k[s],
                        "shard {s}: K reverted {} -> {k} within membership epoch \
                         {epoch} at {t:?} (`{spec}`)",
                        last_k[s]
                    );
                }
                let a = sim.arrivals(s);
                prop_assert!(
                    a >= last_arrivals[s],
                    "shard {s}: arrivals went backwards (`{spec}`)"
                );
                // Exactly-once conservation at a quiescent point.
                let applied = sim.applied(s);
                let buffered = sim.buffered(s) as u64;
                prop_assert!(
                    applied + buffered == a,
                    "shard {s}: {applied} applied + {buffered} buffered != \
                     {a} arrivals at {t:?} (`{spec}`)"
                );
                last_k[s] = k;
                last_epoch[s] = epoch;
                last_arrivals[s] = a;
            }
        }
        // Every shard applied the identical membership sequence.
        for s in 1..n_shards {
            prop_assert!(
                sim.shard_membership_epoch(s) == sim.shard_membership_epoch(0),
                "shards disagree on membership epochs (`{spec}`)"
            );
            prop_assert!(
                sim.shard_live(s) == sim.shard_live(0),
                "shards disagree on live membership (`{spec}`)"
            );
        }
        // The drain applies everything still buffered: nothing lost.
        let arrivals0 = sim.arrivals(0);
        let m = sim.finish().map_err(|e| format!("finish: {e:#}"))?;
        prop_assert!(
            m.gradients_total >= arrivals0,
            "finish lost arrivals (`{spec}`)"
        );
        Ok(())
    });
}

/// DSL fuzz for the membership clauses: every generated `join`/`leave`
/// clause round-trips Display↔parse bitwise (alongside the classic fault
/// clauses), and near-miss garbage always yields a typed error — never a
/// panic.
#[test]
fn prop_membership_clause_dsl_roundtrips_and_rejects_garbage() {
    use hybrid_sgd::coordinator::sim::FaultPlan;

    check("membership-dsl-roundtrip", 150, |g| {
        // A random clause list mixing membership churn with the existing
        // fault kinds.
        let mut clauses: Vec<String> = Vec::new();
        for _ in 0..g.usize_in(1, 6) {
            let t = g.f64_in(0.0, 30.0);
            clauses.push(match g.rng.below(5) {
                0 => format!("join:+{}@{t}", g.usize_in(1, 9)),
                1 => format!("leave:{}@{t}", g.usize_in(0, 12)),
                2 => format!("crash:{}@{t}", g.usize_in(0, 12)),
                3 => format!("restart:{}@{t}", g.usize_in(0, 12)),
                _ => {
                    let b = t + g.f64_in(0.1, 5.0);
                    format!("slow:*@{t}..{b}*{}", g.f64_in(1.1, 9.0))
                }
            });
        }
        let spec = clauses.join(",");
        let plan = FaultPlan::parse(&spec).map_err(|e| format!("`{spec}`: {e:#}"))?;
        // Display → parse is bitwise the identity (the logging/replay
        // contract).
        let logged = plan.to_string();
        let replay =
            FaultPlan::parse(&logged).map_err(|e| format!("replay `{logged}`: {e:#}"))?;
        prop_assert!(replay == plan, "`{spec}` -> `{logged}` changed the plan");
        prop_assert!(
            replay.to_string() == logged,
            "Display is not a fixed point for `{logged}`"
        );

        // Near-miss garbage: mutate one byte of a valid clause list. Parse
        // may still succeed (many mutations stay valid) but must never
        // panic, and the documented malformed shapes always error.
        let mut bytes = logged.clone().into_bytes();
        if !bytes.is_empty() {
            let i = g.rng.below(bytes.len() as u64) as usize;
            bytes[i] = b"@+:.,*x0"[g.rng.below(8) as usize];
            if let Ok(mutated) = String::from_utf8(bytes) {
                let _ = FaultPlan::parse(&mutated); // must not panic
            }
        }
        for bad in [
            format!("join:{}@1", g.usize_in(1, 9)), // missing '+'
            "join:+0@1".to_string(),
            format!("leave:*@{}", g.f64_in(0.0, 9.0)),
            format!("join:+{}@", g.usize_in(1, 9)),
        ] {
            prop_assert!(
                FaultPlan::parse(&bad).is_err(),
                "`{bad}` should be a typed error"
            );
        }
        Ok(())
    });
}

/// Satellite property (robust aggregation, ISSUE 8): with fewer attackers
/// than the trim width, the coordinate-wise trimmed mean / median flush
/// estimate lies between honest order statistics, so ‖θ‖∞ grows at most
/// `flushes × lr × B` where B bounds the honest gradient coordinates —
/// no matter how large the Byzantine contributions are. Attackers here
/// send ±1e6-scaled gradients every round under the sync barrier.
#[test]
fn prop_robust_aggregation_bounds_theta_under_byzantine_minority() {
    use hybrid_sgd::coordinator::AggregateMode;

    check("robust-bounds-theta", 60, |g| {
        let workers = g.usize_in(4, 10);
        // Strict Byzantine minority: a attackers with a <= (W-1)/2, so a
        // trim width of a (trimmed) or (W-1)/2 (median) removes them all.
        let attackers = g.usize_in(1, (workers - 1) / 2);
        let mode = if g.bool() {
            // floor(f*W) == attackers and f < 0.5 for every a <= (W-1)/2.
            AggregateMode::Trimmed((attackers as f64 + 0.4) / workers as f64)
        } else {
            AggregateMode::Median
        };
        let dim = g.usize_in(1, 24);
        let lr = g.f64_in(0.01, 0.3) as f32;
        let rounds = g.usize_in(3, 15);
        let mut agg =
            Aggregator::new(Policy::Sync, dim, workers).with_aggregate(mode.clone());
        let mut ps = ParamStore::new(vec![0.0; dim], lr);
        let mut honest_bound = 0.0f32;
        for round in 0..rounds {
            for w in 0..workers {
                let mut grad = g.vec_f32(dim, 1.0);
                if w < attackers {
                    let factor = if g.bool() { 1e6f32 } else { -1e6 };
                    for x in grad.iter_mut() {
                        *x *= factor;
                    }
                } else {
                    for &x in &grad {
                        honest_bound = honest_bound.max(x.abs());
                    }
                }
                let v = ps.version();
                agg.on_gradient(&mut ps, &grad, w, v, 1.0);
            }
            prop_assert!(
                ps.version() == (round + 1) as u64,
                "{mode}: expected one flush per round, version {} after round {round}",
                ps.version()
            );
            let bound = (round + 1) as f32 * lr * honest_bound * 1.05 + 1e-4;
            for (j, &x) in ps.theta().iter().enumerate() {
                prop_assert!(
                    x.is_finite() && x.abs() <= bound,
                    "{mode} W={workers} a={attackers}: |theta[{j}]|={} \
                     escaped the honest bound {bound} after {} flushes",
                    x.abs(),
                    round + 1
                );
            }
        }
        Ok(())
    });
}

/// Satellite property (robust aggregation, ISSUE 8): on attack-free
/// streams, selecting `aggregate=mean` explicitly is bitwise-identical to
/// the historical default path — same outcomes, same versions, same final
/// parameters — for every policy family and S ∈ {1, 2, 4}. The defense
/// machinery must be invisible unless a non-mean mode is chosen.
#[test]
fn prop_explicit_mean_aggregate_is_bitwise_default() {
    use hybrid_sgd::coordinator::{AdaptiveConfig, AggregateMode};

    check("mean-aggregate-bitwise-default", 40, |g| {
        let workers = g.usize_in(1, 8);
        let dim = g.usize_in(1, 40);
        let policy = match g.rng.below(4) {
            0 => Policy::Async,
            1 => Policy::Sync,
            2 => Policy::Hybrid {
                schedule: random_schedule(g),
                strict: g.bool(),
            },
            _ => Policy::HybridAdaptive {
                cfg: AdaptiveConfig {
                    window: g.usize_in(2, 40),
                    ..Default::default()
                },
                strict: false,
            },
        };
        let lr = g.f64_in(0.01, 0.2) as f32;
        let init = g.vec_f32(dim, 1.0);
        for shards in [1usize, 2, 4] {
            let mut default_m =
                ShardedAggregator::new(policy.clone(), &init, lr, workers, shards);
            let mut explicit_m =
                ShardedAggregator::new(policy.clone(), &init, lr, workers, shards)
                    .with_aggregate(AggregateMode::Mean);
            let n = g.usize_in(1, 200);
            for i in 0..n {
                let grad = g.vec_f32(dim, 1.0);
                let w = g.usize_in(0, workers - 1);
                let loss = g.f64_in(0.0, 4.0) as f32;
                let (vd, ve) = (default_m.version(), explicit_m.version());
                prop_assert!(vd == ve, "{policy} S={shards}: version diverged");
                let out_d = default_m.on_gradient(&grad, w, vd, loss);
                let out_e = explicit_m.on_gradient(&grad, w, ve, loss);
                prop_assert!(
                    out_d == out_e,
                    "{policy} S={shards}: outcome diverged at arrival {i}: \
                     {out_d:?} vs {out_e:?}"
                );
            }
            default_m.drain();
            explicit_m.drain();
            prop_assert!(
                default_m.version() == explicit_m.version(),
                "{policy} S={shards}: update counts diverged"
            );
            prop_assert!(
                default_m.final_params() == explicit_m.final_params(),
                "{policy} S={shards}: explicit mean is not bitwise the default"
            );
        }
        Ok(())
    });
}

/// Strict hybrid at K = W with exactly one outstanding gradient per worker
/// behaves like sync: every flush contains W distinct workers.
#[test]
fn prop_strict_kw_is_sync_like() {
    check("strict-kw-sync", 60, |g| {
        let workers = g.usize_in(2, 8);
        let dim = 3;
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: Schedule::Constant { k: workers },
                strict: true,
            },
            dim,
            workers,
        );
        let mut ps = ParamStore::new(vec![0.0; dim], 0.1);
        // one gradient per worker, round-robin (the strict contract)
        for round in 0..5 {
            for w in 0..workers {
                let grad = g.vec_f32(dim, 1.0);
                let v = ps.version();
                let out = agg.on_gradient(&mut ps, &grad, w, v, 1.0);
                if w + 1 < workers {
                    prop_assert!(
                        matches!(out, Outcome::BufferedBlocked),
                        "round {round}: worker {w} not blocked"
                    );
                } else {
                    match out {
                        Outcome::Flushed {
                            count,
                            distinct_workers,
                            ..
                        } => {
                            prop_assert!(count == workers, "flush count {count}");
                            prop_assert!(
                                distinct_workers == workers,
                                "distinct {distinct_workers}"
                            );
                        }
                        o => prop_assert!(false, "round {round}: expected flush, got {o:?}"),
                    }
                }
            }
        }
        Ok(())
    });
}
